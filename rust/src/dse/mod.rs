//! dse — design-space exploration over (layer-mask × multiplier) configs.
//!
//! A configuration selects one approximate multiplier and the subset of
//! computing layers it replaces (mask bit ci = layer ci approximated,
//! exact elsewhere) — exactly the paper's `2^n` per-AxM space. Evaluation
//! produces a [`DesignPoint`] carrying the trilateral metrics: accuracy
//! drop (approximation), fault vulnerability (FI campaign) and hardware
//! cost (HLS model).

pub mod cache;
pub mod pareto;

pub use pareto::pareto_front;

use crate::axmul::{self, Lut};
use crate::dataset::TestSet;
use crate::faultsim::{run_campaign, CampaignParams};
use crate::hwmodel;
use crate::simnet::{Buffers, Engine, QNet};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// One evaluated design point (a row of the paper's Table III).
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub net: String,
    pub mult: String,
    pub mask: u64,
    /// paper-style layer string, e.g. "0-1-101"
    pub config_string: String,
    /// exact-quantized accuracy on the evaluation subset (the "Base")
    pub base_acc: f64,
    /// AxDNN accuracy (no faults)
    pub ax_acc: f64,
    /// accuracy drop due to approximation, percent points
    pub acc_drop_pct: f64,
    /// mean accuracy under fault injection (NaN if FI skipped)
    pub fi_mean_acc: f64,
    /// AxDNN accuracy drop due to FI, percent points (the paper's fault
    /// vulnerability; NaN if FI skipped)
    pub fault_vuln_pct: f64,
    /// faults actually sampled for the FI estimate (0 if FI skipped; less
    /// than the campaign size when the fidelity ladder stopped early)
    pub fi_faults: usize,
    /// 95% CI half-width of `fault_vuln_pct`, percent points (NaN if FI
    /// skipped; legacy cache entries load as NaN)
    pub fi_ci95_pp: f64,
    pub cycles: u64,
    pub luts: u64,
    pub ffs: u64,
    pub util_pct: f64,
    pub power_mw: f64,
}

impl DesignPoint {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("net", json::str(&self.net)),
            ("mult", json::str(&self.mult)),
            ("mask", json::num(self.mask as f64)),
            ("config", json::str(&self.config_string)),
            ("base_acc", json::num(self.base_acc)),
            ("ax_acc", json::num(self.ax_acc)),
            ("acc_drop_pct", json::num(self.acc_drop_pct)),
            (
                "fi_mean_acc",
                if self.fi_mean_acc.is_nan() { Json::Null } else { json::num(self.fi_mean_acc) },
            ),
            (
                "fault_vuln_pct",
                if self.fault_vuln_pct.is_nan() {
                    Json::Null
                } else {
                    json::num(self.fault_vuln_pct)
                },
            ),
            ("fi_faults", json::num(self.fi_faults as f64)),
            (
                "fi_ci95_pp",
                if self.fi_ci95_pp.is_nan() { Json::Null } else { json::num(self.fi_ci95_pp) },
            ),
            ("cycles", json::num(self.cycles as f64)),
            ("luts", json::num(self.luts as f64)),
            ("ffs", json::num(self.ffs as f64)),
            ("util_pct", json::num(self.util_pct)),
            ("power_mw", json::num(self.power_mw)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<DesignPoint> {
        let nan_or = |k: &str| match j.get(k) {
            Some(Json::Null) | None => f64::NAN,
            Some(v) => v.as_f64().unwrap_or(f64::NAN),
        };
        Some(DesignPoint {
            net: j.get("net")?.as_str()?.to_string(),
            mult: j.get("mult")?.as_str()?.to_string(),
            mask: j.get("mask")?.as_i64()? as u64,
            config_string: j.get("config")?.as_str()?.to_string(),
            base_acc: j.get("base_acc")?.as_f64()?,
            ax_acc: j.get("ax_acc")?.as_f64()?,
            acc_drop_pct: j.get("acc_drop_pct")?.as_f64()?,
            fi_mean_acc: nan_or("fi_mean_acc"),
            fault_vuln_pct: nan_or("fault_vuln_pct"),
            // both absent from pre-ladder cache files: default, don't fail
            fi_faults: j.get("fi_faults").and_then(|v| v.as_i64()).unwrap_or(0) as usize,
            fi_ci95_pp: nan_or("fi_ci95_pp"),
            cycles: j.get("cycles")?.as_i64()? as u64,
            luts: j.get("luts")?.as_i64()? as u64,
            ffs: j.get("ffs")?.as_i64()? as u64,
            util_pct: j.get("util_pct")?.as_f64()?,
            power_mw: j.get("power_mw")?.as_f64()?,
        })
    }
}

/// All 2^n layer masks (0 = fully exact ... 2^n-1 = fully approximated).
pub fn enumerate_masks(n_comp: usize) -> Vec<u64> {
    assert!(n_comp < 63);
    (0..(1u64 << n_comp)).collect()
}

/// Parse a paper-style configuration string ("0-1-101") into a mask over
/// computing layers (dashes ignored).
pub fn mask_from_config_string(s: &str) -> Result<u64, String> {
    let mut mask = 0u64;
    let mut ci = 0;
    for ch in s.chars() {
        match ch {
            '1' => {
                mask |= 1 << ci;
                ci += 1;
            }
            '0' => ci += 1,
            '-' | ' ' => {}
            other => return Err(format!("bad config char {other:?} in {s:?}")),
        }
    }
    Ok(mask)
}

/// Binds a network + data + LUT set for repeated configuration evaluation.
pub struct Evaluator<'a> {
    pub net: &'a QNet,
    pub data: &'a TestSet,
    pub luts: &'a BTreeMap<String, Lut>,
    /// images used for (fault-free) accuracy evaluation
    pub eval_images: usize,
    pub fi: CampaignParams,
    base_acc: f64,
}

impl<'a> Evaluator<'a> {
    pub fn new(
        net: &'a QNet,
        data: &'a TestSet,
        luts: &'a BTreeMap<String, Lut>,
        eval_images: usize,
        fi: CampaignParams,
    ) -> Evaluator<'a> {
        let exact = &luts["exact"];
        let eng = Engine::uniform(net, exact);
        let mut buf = Buffers::for_net(net);
        let base_acc = eng.accuracy(&data.take(eval_images), &mut buf);
        Evaluator { net, data, luts, eval_images, fi, base_acc }
    }

    pub fn base_acc(&self) -> f64 {
        self.base_acc
    }

    /// Per-layer LUT selection for (mult, mask).
    pub fn config_luts(&self, mult: &str, mask: u64) -> Vec<&Lut> {
        let exact = &self.luts["exact"];
        let axm = self
            .luts
            .get(mult)
            .unwrap_or_else(|| panic!("multiplier {mult} not loaded"));
        (0..self.net.n_comp())
            .map(|ci| if mask >> ci & 1 == 1 { axm } else { exact })
            .collect()
    }

    /// Evaluate one configuration; `with_fi=false` skips the fault
    /// campaign (accuracy + hardware only — used by the full 2^n sweep
    /// pre-filter).
    pub fn evaluate(&self, mult: &str, mask: u64, with_fi: bool) -> DesignPoint {
        let names: Vec<&str> = (0..self.net.n_comp())
            .map(|ci| if mask >> ci & 1 == 1 { mult } else { "exact" })
            .collect();
        let mut p = self.evaluate_assignment(&names, with_fi);
        // keep the caller's multiplier label even for mask 0 / fully-exact
        p.mult = mult.to_string();
        p
    }

    /// Bind one engine for a per-layer multiplier assignment.
    pub fn assignment_engine(&self, names: &[&str]) -> Engine<'_> {
        assert_eq!(names.len(), self.net.n_comp(), "one multiplier per computing layer");
        let luts: Vec<&Lut> = names
            .iter()
            .map(|n| self.luts.get(*n).unwrap_or_else(|| panic!("multiplier {n} not loaded")))
            .collect();
        Engine::new(self.net, luts)
    }

    /// Fault-free AxDNN accuracy of an engine on the evaluation subset.
    pub fn ax_accuracy(&self, engine: &Engine) -> f64 {
        let mut buf = Buffers::for_net(self.net);
        engine.accuracy(&self.data.take(self.eval_images), &mut buf)
    }

    /// Analytic HLS cost of an assignment.
    pub fn assignment_hw(&self, names: &[&str]) -> hwmodel::HwReport {
        let mults: Vec<&axmul::Multiplier> =
            names.iter().map(|n| axmul::by_name(n).expect("catalog")).collect();
        hwmodel::estimate(self.net, &mults)
    }

    /// Analytic HLS cost of an assignment under per-layer selective
    /// hardening (the PR 6 protection surcharge; all-`None` levels reduce
    /// to [`assignment_hw`](Self::assignment_hw) exactly).
    pub fn assignment_hw_hardened(
        &self,
        names: &[&str],
        levels: &[crate::faultsim::HardenLevel],
    ) -> hwmodel::HwReport {
        let mults: Vec<&axmul::Multiplier> =
            names.iter().map(|n| axmul::by_name(n).expect("catalog")).collect();
        hwmodel::estimate_hardened(self.net, &mults, levels)
    }

    /// `(mult label, approximation mask)` for an assignment: the shared
    /// multiplier when homogeneous, `"exact"` when fully exact, `"mixed"`
    /// otherwise.
    pub fn assignment_label(names: &[&str]) -> (String, u64) {
        let mut mask = 0u64;
        let mut label: Option<&str> = None;
        let mut mixed = false;
        for (ci, n) in names.iter().enumerate() {
            if *n != "exact" {
                mask |= 1 << ci;
                match label {
                    None => label = Some(n),
                    Some(l) if l != *n => mixed = true,
                    _ => {}
                }
            }
        }
        let mult = if mixed { "mixed" } else { label.unwrap_or("exact") };
        (mult.to_string(), mask)
    }

    /// Assemble a [`DesignPoint`] from staged pieces (accuracy leg + an
    /// optional FI estimate). This is the composition point shared by the
    /// monolithic [`evaluate_assignment`](Self::evaluate_assignment) and
    /// the fidelity ladder in [`crate::eval`].
    pub fn compose_point(
        &self,
        names: &[&str],
        ax_acc: f64,
        fi: Option<&FiEstimate>,
    ) -> DesignPoint {
        let hw = self.assignment_hw(names);
        let (mult, mask) = Self::assignment_label(names);
        DesignPoint {
            net: self.net.name.clone(),
            mult,
            mask,
            config_string: self.net.config_string(mask),
            base_acc: self.base_acc,
            ax_acc,
            acc_drop_pct: (self.base_acc - ax_acc) * 100.0,
            fi_mean_acc: fi.map_or(f64::NAN, |e| e.mean_acc),
            fault_vuln_pct: fi.map_or(f64::NAN, |e| e.vuln_pct),
            fi_faults: fi.map_or(0, |e| e.n_faults),
            fi_ci95_pp: fi.map_or(f64::NAN, |e| e.ci95_pp),
            cycles: hw.cycles,
            luts: hw.luts,
            ffs: hw.ffs,
            util_pct: hw.util_pct,
            power_mw: hw.power_mw,
        }
    }

    /// Evaluate a generalized per-layer multiplier assignment (`names[ci]`
    /// runs on computing layer ci) at full fidelity. The paper's
    /// `(mult, mask)` configs are the homogeneous special case; see
    /// [`assignment_label`](Self::assignment_label) for the returned
    /// `mult`/`mask` conventions. The staged ladder in [`crate::eval`]
    /// generalizes this with cheap screening tiers and CI-gated campaigns;
    /// this monolithic path is kept for the paper's exhaustive sweep and
    /// is bit-identical to the ladder at `FiFull` with epsilon 0.
    pub fn evaluate_assignment(&self, names: &[&str], with_fi: bool) -> DesignPoint {
        let engine = self.assignment_engine(names);
        let ax_acc = self.ax_accuracy(&engine);
        let fi = if with_fi {
            // vulnerability relative to *this* AxDNN's fault-free accuracy
            // on the FI subset (paper: [AxDNN - FI on AxDNN])
            Some(FiEstimate::from_campaign(&run_campaign(&engine, self.data, &self.fi)))
        } else {
            None
        };
        self.compose_point(names, ax_acc, fi.as_ref())
    }
}

/// The reliability leg of a design point, at whatever fidelity it was
/// sampled.
#[derive(Debug, Clone, Copy)]
pub struct FiEstimate {
    /// mean accuracy across the sampled faults
    pub mean_acc: f64,
    /// fault vulnerability, percent points
    pub vuln_pct: f64,
    /// 95% CI half-width of `vuln_pct`, percent points
    pub ci95_pp: f64,
    /// faults actually sampled
    pub n_faults: usize,
}

impl FiEstimate {
    pub fn from_campaign(r: &crate::faultsim::CampaignResult) -> FiEstimate {
        FiEstimate {
            mean_acc: r.mean_fault_acc,
            vuln_pct: (r.base_acc - r.mean_fault_acc) * 100.0,
            ci95_pp: r.ci95 * 100.0,
            n_faults: r.n_faults,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_enumeration() {
        assert_eq!(enumerate_masks(3).len(), 8);
        assert_eq!(enumerate_masks(0), vec![0]);
    }

    #[test]
    fn config_string_roundtrip() {
        for s in ["111", "101", "1-1-011", "0-0-11-0-100"] {
            let mask = mask_from_config_string(s).unwrap();
            let bits: String = s.chars().filter(|c| *c != '-').collect();
            let mut expect = 0u64;
            for (i, c) in bits.chars().enumerate() {
                if c == '1' {
                    expect |= 1 << i;
                }
            }
            assert_eq!(mask, expect, "{s}");
        }
        assert!(mask_from_config_string("1x0").is_err());
    }

    #[test]
    fn design_point_json_roundtrip() {
        let p = DesignPoint {
            net: "mlp3".into(),
            mult: "mul8s_1kvp_s".into(),
            mask: 0b101,
            config_string: "101".into(),
            base_acc: 0.9,
            ax_acc: 0.85,
            acc_drop_pct: 5.0,
            fi_mean_acc: 0.8,
            fault_vuln_pct: 5.0,
            fi_faults: 150,
            fi_ci95_pp: 0.75,
            cycles: 12345,
            luts: 1000,
            ffs: 900,
            util_pct: 0.99,
            power_mw: 21.5,
        };
        let back = DesignPoint::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn design_point_loads_legacy_json_without_fi_confidence_fields() {
        // records persisted before the fidelity ladder carry neither
        // fi_faults nor fi_ci95_pp — they must still parse (criterion:
        // cached PR 1 result files keep loading)
        let legacy = r#"{"net":"lenet5","mult":"mul8s_1kvp_s","mask":3,"config":"1-1-000",
            "base_acc":0.9,"ax_acc":0.88,"acc_drop_pct":2.0,"fi_mean_acc":0.8,
            "fault_vuln_pct":8.0,"cycles":100,"luts":10,"ffs":20,"util_pct":50.0,
            "power_mw":2.0}"#;
        let p = DesignPoint::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(p.fi_faults, 0);
        assert!(p.fi_ci95_pp.is_nan());
        assert_eq!(p.fault_vuln_pct, 8.0);
    }

    #[test]
    fn design_point_json_nan_fi() {
        let mut p = DesignPoint {
            net: "m".into(),
            mult: "exact".into(),
            mask: 0,
            config_string: "000".into(),
            base_acc: 0.9,
            ax_acc: 0.9,
            acc_drop_pct: 0.0,
            fi_mean_acc: f64::NAN,
            fault_vuln_pct: f64::NAN,
            fi_faults: 0,
            fi_ci95_pp: f64::NAN,
            cycles: 1,
            luts: 1,
            ffs: 1,
            util_pct: 0.1,
            power_mw: 1.0,
        };
        let back = DesignPoint::from_json(&p.to_json()).unwrap();
        assert!(back.fi_mean_acc.is_nan() && back.fault_vuln_pct.is_nan());
        p.fi_mean_acc = 0.5;
        p.fault_vuln_pct = 40.0;
        let back = DesignPoint::from_json(&p.to_json()).unwrap();
        assert_eq!(back.fi_mean_acc, 0.5);
    }
}
