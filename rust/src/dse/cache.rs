//! Result cache: append-only JSONL of evaluated design points, keyed by
//! (net, mult, mask, evaluation parameters). Lets the coordinator resume
//! interrupted sweeps and share FI results between experiments (Table III
//! rows reuse Fig. 3 sweep points, like the paper's iterative flow).
//!
//! The store is **sharded**: records append to N lock-striped segments
//! under `<file>.shards/shard-<i>.jsonl` (FNV-1a of the string key picks
//! the shard), so concurrent readers stripe across N mutexes instead of
//! serializing on one map + one `BufWriter`. The original single file at
//! the base path remains fully supported: it is loaded first (legacy
//! caches work transparently, segments override on key collision) and it
//! is the target `compact` merges every segment back into. Durability
//! marks are per-segment ([`CacheMark`]): `flush` fsyncs each dirty shard
//! and records every segment's byte length; `rollback_to` truncates
//! *every* segment back to a mark, which is what keeps the crash-safe
//! resume contract (PR 8) intact across the sharded layout.

use super::DesignPoint;
use crate::eval::Fidelity;
use crate::faultsim::FaultModelKind;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Evaluation-parameter fingerprint: results are only reusable when the
/// campaign parameters match.
///
/// Two key shapes share the store: the legacy homogeneous shape
/// `(net, mult, mask)` from the paper's single-AxM sweeps, and the
/// generalized per-layer assignment shape (`assignment` = comma-joined
/// multiplier name per computing layer) used by the `search` subsystem.
/// [`CacheKey::for_assignment`] canonicalizes: any assignment expressible
/// as `(mult, mask)` renders the *legacy* string key, so heterogeneous
/// searches get hits on results that exhaustive sweeps already persisted
/// (and vice versa), and pre-existing cache files stay valid.
///
/// Keys carry the [`Fidelity`] the point was computed at. The two legacy
/// tiers render the historical `|0` / `|1` `with_fi` suffix unchanged —
/// so untagged entries in pre-ladder cache files read back as
/// [`Fidelity::FiFull`] (or [`Fidelity::Accuracy`] for `with_fi = 0`)
/// exactly as they were written — while the new tiers append a `fid:`
/// marker so a screen-grade estimate can never shadow a full result.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CacheKey {
    pub net: String,
    pub mult: String,
    pub mask: u64,
    /// canonical per-layer multiplier names (empty for homogeneous keys,
    /// which use the legacy `(mult, mask)` encoding)
    pub assignment: String,
    pub n_faults: usize,
    pub n_images: usize,
    pub eval_images: usize,
    pub seed: u64,
    /// fidelity tier the cached point was evaluated at
    pub fidelity: Fidelity,
    /// fault model the FI numbers were computed under. [`FaultModelKind::BitFlip`]
    /// (the historical model, and the default) renders *nothing* — every
    /// pre-PR-6 untagged cache line reads back as a BitFlip record — while
    /// the other models append a `fm:` tag so e.g. a stuck-at vulnerability
    /// can never shadow a bit-flip one.
    pub fault_model: FaultModelKind,
}

impl CacheKey {
    /// Canonical key for a per-layer multiplier assignment. Homogeneous
    /// assignments (all non-exact layers share one multiplier, or fully
    /// exact) reduce to the legacy `(net, mult, mask)` key — the
    /// backward-compat path for existing cache files.
    pub fn for_assignment(
        net: &str,
        names: &[&str],
        n_faults: usize,
        n_images: usize,
        eval_images: usize,
        seed: u64,
        fidelity: Fidelity,
    ) -> CacheKey {
        let mut mask = 0u64;
        let mut hom: Option<&str> = None;
        let mut mixed = false;
        for (ci, n) in names.iter().enumerate() {
            if *n != "exact" {
                mask |= 1 << ci;
                match hom {
                    None => hom = Some(n),
                    Some(h) if h != *n => mixed = true,
                    _ => {}
                }
            }
        }
        let (mult, assignment) = if mixed {
            ("mixed".to_string(), names.join(","))
        } else {
            (hom.unwrap_or("exact").to_string(), String::new())
        };
        CacheKey {
            net: net.to_string(),
            mult,
            mask,
            assignment,
            n_faults,
            n_images,
            eval_images,
            seed,
            fidelity,
            fault_model: FaultModelKind::BitFlip,
        }
    }

    /// Same key under a different fault model (builder for zoo campaigns).
    pub fn with_fault_model(mut self, fault_model: FaultModelKind) -> CacheKey {
        self.fault_model = fault_model;
        self
    }

    /// Fidelity rendering: legacy tiers keep the historical `with_fi` bit
    /// verbatim (existing cache files stay valid); ladder-only tiers tag
    /// on a `fid:` marker.
    fn fidelity_suffix(&self) -> &'static str {
        match self.fidelity {
            Fidelity::Accuracy => "0",
            Fidelity::FiFull => "1",
            Fidelity::HwOnly => "0|fid:hw",
            Fidelity::FiScreen => "1|fid:screen",
        }
    }

    /// Fault-model rendering: BitFlip is the untagged legacy encoding.
    fn fault_model_suffix(&self) -> String {
        match self.fault_model {
            FaultModelKind::BitFlip => String::new(),
            other => format!("|fm:{}", other.name()),
        }
    }

    fn to_string_key(&self) -> String {
        if self.assignment.is_empty() {
            format!(
                "{}|{}|{:x}|{}|{}|{}|{}|{}{}",
                self.net,
                self.mult,
                self.mask,
                self.n_faults,
                self.n_images,
                self.eval_images,
                self.seed,
                self.fidelity_suffix(),
                self.fault_model_suffix()
            )
        } else {
            format!(
                "{}|cfg:{}|{}|{}|{}|{}|{}{}",
                self.net,
                self.assignment,
                self.n_faults,
                self.n_images,
                self.eval_images,
                self.seed,
                self.fidelity_suffix(),
                self.fault_model_suffix()
            )
        }
    }
}

/// What `ResultCache::open` found on disk: total non-empty lines, how many
/// loaded cleanly, and how many were quarantined (torn by a crash mid-append,
/// or otherwise unparseable). Quarantined lines are skipped — never aborted
/// on — so a cache file damaged by `kill -9` still serves every record that
/// made it to disk intact. `repro cache verify` prints this; `repro cache
/// compact` rewrites the file so the next report is clean.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// non-empty lines seen in the file
    pub lines: usize,
    /// lines that parsed into a (key, point) record
    pub loaded: usize,
    /// torn / malformed lines skipped
    pub quarantined: usize,
}

impl RecoveryReport {
    pub fn is_clean(&self) -> bool {
        self.quarantined == 0
    }
}

/// Recover the stored fidelity name from a string key (used when compacting:
/// the original `CacheKey` is gone, but the suffix encodes the tier).
fn fidelity_from_string_key(key: &str) -> &'static str {
    // strip an optional fault-model tag, then read the fidelity suffix
    let base = match key.rfind("|fm:") {
        Some(i) => &key[..i],
        None => key,
    };
    if base.ends_with("|fid:screen") {
        "screen"
    } else if base.ends_with("|fid:hw") {
        "hw"
    } else if base.ends_with("|0") {
        "acc"
    } else {
        "full"
    }
}

/// Per-segment durability mark: the byte length of the base file plus
/// every shard segment at a flush. The run journal stores one of these at
/// each checkpoint so `--resume` can [`ResultCache::rollback_to`] exactly
/// the bytes the checkpoint saw — in every segment, not just one file.
///
/// Pre-shard journals only recorded a single length; [`CacheMark::legacy`]
/// lifts it (that length belongs to the base file, and every shard segment
/// rolls back to empty).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheMark {
    /// durable bytes of the single-file (legacy / compacted) segment
    pub base: u64,
    /// durable bytes of `shard-<i>.jsonl`, indexed by shard
    pub shards: Vec<u64>,
}

impl CacheMark {
    /// Mark equivalent to a pre-shard journal's single `cache_bytes` value.
    pub fn legacy(bytes: u64) -> CacheMark {
        CacheMark { base: bytes, shards: Vec::new() }
    }

    /// Total durable bytes across every segment (the journal's legacy
    /// `cache_bytes` field keeps reporting this).
    pub fn total(&self) -> u64 {
        self.base + self.shards.iter().sum::<u64>()
    }
}

/// Default shard count when neither existing segments nor
/// `DEEPAXE_CACHE_SHARDS` say otherwise.
const DEFAULT_SHARDS: usize = 8;

/// FNV-1a of the string key, reduced to a shard index. Stable across runs
/// — the same key always appends to the same segment for a given count.
fn shard_of(key: &str, n: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    (h % n.max(1) as u64) as usize
}

fn shard_dir(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "cache".into());
    path.with_file_name(format!("{name}.shards"))
}

fn shard_path(path: &Path, i: usize) -> PathBuf {
    shard_dir(path).join(format!("shard-{i}.jsonl"))
}

/// Shard count already on disk (max segment index + 1), if any. The
/// existing layout is sticky: it wins over env/default so reopened caches
/// keep appending to the segments they already have.
fn existing_shard_count(path: &Path) -> Option<usize> {
    let rd = std::fs::read_dir(shard_dir(path)).ok()?;
    let mut max: Option<usize> = None;
    for e in rd.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(i) = name
            .strip_prefix("shard-")
            .and_then(|s| s.strip_suffix(".jsonl"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            max = Some(max.map_or(i, |m| m.max(i)));
        }
    }
    max.map(|m| m + 1)
}

fn file_len(path: &Path) -> u64 {
    std::fs::metadata(path).map(|m| m.len()).unwrap_or(0)
}

fn truncate_file(path: &Path, bytes: u64) -> std::io::Result<()> {
    if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
        if f.metadata()?.len() > bytes {
            f.set_len(bytes)?;
            f.sync_all()?;
        }
    }
    Ok(())
}

/// Parse one JSONL segment, quarantining (never aborting on) torn lines.
fn load_segment(path: &Path, report: &mut RecoveryReport) -> Vec<(String, DesignPoint)> {
    let mut out = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            report.lines += 1;
            match Json::parse(line) {
                Ok(j) => {
                    let key = j.get("key").and_then(|k| k.as_str()).map(str::to_string);
                    let point = j.get("point").and_then(DesignPoint::from_json);
                    match (key, point) {
                        (Some(k), Some(p)) => {
                            report.loaded += 1;
                            out.push((k, p));
                        }
                        _ => {
                            report.quarantined += 1;
                            eprintln!("cache {}: line {} malformed, skipped", path.display(), ln + 1)
                        }
                    }
                }
                Err(e) => {
                    report.quarantined += 1;
                    eprintln!("cache {}: line {} unparseable ({e}), skipped", path.display(), ln + 1)
                }
            }
        }
    }
    out
}

/// One lock stripe: a shard's in-memory map, its lazily opened segment
/// appender, and what loading its segment found.
#[derive(Default)]
struct Shard {
    map: BTreeMap<String, DesignPoint>,
    writer: Option<BufWriter<File>>,
    report: RecoveryReport,
}

pub struct ResultCache {
    /// base (legacy single-file / compact-target) segment path
    path: PathBuf,
    /// lock-striped shards; a key's stripe is `shard_of(key, len)`
    shards: Vec<Mutex<Shard>>,
    /// flush after every append (the pre-journal behavior, and the default);
    /// journaled searches turn this off and flush at checkpoints instead
    autoflush: bool,
    base_report: RecoveryReport,
    /// aggregate of base + every shard segment
    report: RecoveryReport,
}

impl ResultCache {
    /// Load (or start) the cache at `path`. Unparseable lines are skipped
    /// with a warning rather than failing the run; the tally is kept in
    /// [`ResultCache::recovery_report`]. Shard count: existing segments on
    /// disk win, else `DEEPAXE_CACHE_SHARDS`, else 8.
    pub fn open(path: impl AsRef<Path>) -> ResultCache {
        let n = crate::util::cli::env_usize("DEEPAXE_CACHE_SHARDS", DEFAULT_SHARDS).max(1);
        ResultCache::open_with_shards(path, n)
    }

    /// [`ResultCache::open`] with an explicit shard count (tests, tools).
    /// Segments already on disk still win — reopening a cache never
    /// changes its layout mid-life.
    pub fn open_with_shards(path: impl AsRef<Path>, shards: usize) -> ResultCache {
        let path = path.as_ref().to_path_buf();
        let n = existing_shard_count(&path).unwrap_or(shards.max(1));
        let mut stripes: Vec<Shard> = (0..n).map(|_| Shard::default()).collect();
        // base segment first, shard segments after: on key collision the
        // segment record (the newer write) wins
        let mut base_report = RecoveryReport::default();
        for (k, p) in load_segment(&path, &mut base_report) {
            stripes[shard_of(&k, n)].map.insert(k, p);
        }
        for i in 0..n {
            let mut rep = RecoveryReport::default();
            // records are re-striped by hash at load, so a cache whose
            // shard count changed on disk still serves every record
            for (k, p) in load_segment(&shard_path(&path, i), &mut rep) {
                stripes[shard_of(&k, n)].map.insert(k, p);
            }
            stripes[i].report = rep;
        }
        let mut report = base_report.clone();
        for s in &stripes {
            report.lines += s.report.lines;
            report.loaded += s.report.loaded;
            report.quarantined += s.report.quarantined;
        }
        ResultCache {
            path,
            shards: stripes.into_iter().map(Mutex::new).collect(),
            autoflush: true,
            base_report,
            report,
        }
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of lock stripes / append segments.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// What `open` found on disk, aggregated across every segment.
    pub fn recovery_report(&self) -> &RecoveryReport {
        &self.report
    }

    /// Per-segment load reports: the base file first, then every shard
    /// segment present on disk. `repro cache verify` prints these.
    pub fn segment_reports(&self) -> Vec<(String, RecoveryReport)> {
        let mut out = vec![(self.path.display().to_string(), self.base_report.clone())];
        for (i, s) in self.shards.iter().enumerate() {
            let sp = shard_path(&self.path, i);
            if sp.exists() {
                out.push((sp.display().to_string(), s.lock().unwrap().report.clone()));
            }
        }
        out
    }

    /// Sum of [`segment_reports`](Self::segment_reports): the one-line
    /// corruption tally across the base file and every shard segment.
    /// `repro cache verify` prints it after the per-segment breakdown so
    /// a sharded store's health is visible at a glance.
    pub fn total_report(&self) -> RecoveryReport {
        let mut total = RecoveryReport::default();
        for (_, r) in self.segment_reports() {
            total.lines += r.lines;
            total.loaded += r.loaded;
            total.quarantined += r.quarantined;
        }
        total
    }

    /// When off, appends stay in the shard writers' buffers until
    /// [`ResultCache::flush`] — journaled searches flush at checkpoint
    /// boundaries so the on-disk cache never runs ahead of the journal.
    pub fn set_autoflush(&mut self, on: bool) {
        self.autoflush = on;
    }

    /// Look a key up in its shard. Takes `&self` — concurrent readers
    /// stripe across the shard mutexes instead of one global lock.
    pub fn get(&self, key: &CacheKey) -> Option<DesignPoint> {
        let k = key.to_string_key();
        self.shards[shard_of(&k, self.shards.len())].lock().unwrap().map.get(&k).cloned()
    }

    /// Every cached `(string key, point)` pair, in key order across all
    /// shards. The string key layout is documented on [`CacheKey`];
    /// consumers that need the per-layer assignment back out of a key
    /// (e.g. warm-starting a search from cached frontiers) parse the
    /// `cfg:` / legacy segments.
    pub fn entries(&self) -> Vec<(String, DesignPoint)> {
        let mut all: Vec<(String, DesignPoint)> = Vec::new();
        for s in &self.shards {
            let s = s.lock().unwrap();
            all.extend(s.map.iter().map(|(k, v)| (k.clone(), v.clone())));
        }
        all.sort_by(|a, b| a.0.cmp(&b.0));
        all
    }

    /// Insert + append to the key's shard segment. Records are tagged with
    /// the fidelity they were computed at; pre-ladder readers ignore the
    /// extra field, pre-ladder *writers* never produced it — which is
    /// fine, because their keys only ever encoded the two legacy tiers.
    pub fn put(&mut self, key: &CacheKey, point: DesignPoint) -> std::io::Result<()> {
        let k = key.to_string_key();
        let record = json::obj(vec![
            ("key", json::str(k.as_str())),
            ("fidelity", json::str(key.fidelity.name())),
            ("point", point.to_json()),
        ]);
        let i = shard_of(&k, self.shards.len());
        let seg = shard_path(&self.path, i);
        let autoflush = self.autoflush;
        let shard = self.shards[i].get_mut().unwrap();
        if shard.writer.is_none() {
            if let Some(parent) = seg.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let f = std::fs::OpenOptions::new().create(true).append(true).open(&seg)?;
            shard.writer = Some(BufWriter::new(f));
        }
        let w = shard.writer.as_mut().unwrap();
        writeln!(w, "{record}")?;
        if autoflush {
            w.flush()?;
        }
        shard.map.insert(k, point);
        Ok(())
    }

    /// Flush buffered appends (fsync included, **per shard**) and return
    /// the durable byte length of every segment. The journal records the
    /// mark at each checkpoint so a resumed run can roll the cache back to
    /// exactly the bytes the checkpoint saw.
    pub fn flush(&mut self) -> CacheMark {
        let mut mark =
            CacheMark { base: file_len(&self.path), shards: Vec::with_capacity(self.shards.len()) };
        for (i, s) in self.shards.iter_mut().enumerate() {
            let shard = s.get_mut().unwrap();
            if let Some(w) = shard.writer.as_mut() {
                let _ = w.flush();
                let _ = w.get_ref().sync_all();
            }
            mark.shards.push(file_len(&shard_path(&self.path, i)));
        }
        mark
    }

    /// Truncate **every** segment back to `mark` (a mark previously
    /// returned by [`ResultCache::flush`]) and reload. Used on `--resume`:
    /// appends made after the checkpoint being resumed from are discarded
    /// — in all shards, so no segment can run ahead of the journal — and
    /// replay re-derives them deterministically instead of double-counting.
    /// A [`CacheMark::legacy`] mark empties every shard segment.
    pub fn rollback_to(&mut self, mark: &CacheMark) -> std::io::Result<()> {
        let n = self.shards.len();
        for s in self.shards.iter_mut() {
            // drop (and flush) the appenders before truncating
            s.get_mut().unwrap().writer = None;
        }
        truncate_file(&self.path, mark.base)?;
        for i in 0..n {
            truncate_file(&shard_path(&self.path, i), mark.shards.get(i).copied().unwrap_or(0))?;
        }
        let autoflush = self.autoflush;
        *self = ResultCache::open_with_shards(&self.path, n);
        self.autoflush = autoflush;
        Ok(())
    }

    /// Merge every segment into one clean, deduplicated base file: one
    /// line per surviving record, in key order, written atomically (temp
    /// file + rename + dir fsync) so a crash mid-compact leaves the old
    /// layout intact; shard segments are removed after the rename lands.
    /// Quarantined lines are dropped for good; returns the number of
    /// records written.
    pub fn compact(&mut self) -> std::io::Result<usize> {
        let entries = self.entries();
        let mut out = String::new();
        for (k, p) in &entries {
            let record = json::obj(vec![
                ("key", json::str(k.as_str())),
                ("fidelity", json::str(fidelity_from_string_key(k))),
                ("point", p.to_json()),
            ]);
            out.push_str(&record.to_string());
            out.push('\n');
        }
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::recovery::atomic_write(&self.path, &out)?;
        for (i, s) in self.shards.iter_mut().enumerate() {
            let shard = s.get_mut().unwrap();
            shard.writer = None; // the appender's fd goes stale across removal
            shard.report = RecoveryReport::default();
            let _ = std::fs::remove_file(shard_path(&self.path, i));
        }
        let _ = std::fs::remove_dir(shard_dir(&self.path));
        self.base_report =
            RecoveryReport { lines: entries.len(), loaded: entries.len(), quarantined: 0 };
        self.report = self.base_report.clone();
        Ok(entries.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(net: &str, mask: u64) -> DesignPoint {
        DesignPoint {
            net: net.into(),
            mult: "exact".into(),
            mask,
            config_string: "000".into(),
            base_acc: 0.9,
            ax_acc: 0.9,
            acc_drop_pct: 0.0,
            fi_mean_acc: 0.8,
            fault_vuln_pct: 10.0,
            fi_faults: 10,
            fi_ci95_pp: 0.5,
            cycles: 100,
            luts: 10,
            ffs: 20,
            util_pct: 0.5,
            power_mw: 2.0,
        }
    }

    fn key(net: &str, mask: u64) -> CacheKey {
        CacheKey {
            net: net.into(),
            mult: "exact".into(),
            mask,
            assignment: String::new(),
            n_faults: 10,
            n_images: 20,
            eval_images: 30,
            seed: 1,
            fidelity: Fidelity::FiFull,
            fault_model: FaultModelKind::BitFlip,
        }
    }

    /// Remove a cache's base file AND its shard segment directory, so a
    /// stale layout from an earlier run can't leak into a test.
    fn reset(p: &Path) {
        let _ = std::fs::remove_file(p);
        let _ = std::fs::remove_dir_all(shard_dir(p));
    }

    #[test]
    fn put_get_persist() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        {
            let mut c = ResultCache::open(&p);
            assert!(c.is_empty());
            c.put(&key("mlp3", 1), point("mlp3", 1)).unwrap();
            c.put(&key("mlp3", 2), point("mlp3", 2)).unwrap();
            assert_eq!(c.len(), 2);
        }
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&key("mlp3", 1)).unwrap().mask, 1);
        assert!(c.get(&key("mlp3", 3)).is_none());
        // different params -> different key -> miss
        let mut other = key("mlp3", 1);
        other.n_faults = 99;
        assert!(c.get(&other).is_none());
    }

    #[test]
    fn malformed_lines_skipped() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        std::fs::write(&p, "not json\n{\"key\": \"k\"}\n").unwrap();
        let c = ResultCache::open(&p);
        assert!(c.is_empty());
    }

    #[test]
    fn homogeneous_assignment_hits_legacy_keys() {
        // a heterogeneous-genotype lookup whose assignment happens to be
        // homogeneous must produce the exact legacy key string — existing
        // cache files keep working
        let legacy = CacheKey {
            net: "mlp3".into(),
            mult: "mul8s_1kvp_s".into(),
            mask: 0b101,
            assignment: String::new(),
            n_faults: 10,
            n_images: 20,
            eval_images: 30,
            seed: 1,
            fidelity: Fidelity::FiFull,
            fault_model: FaultModelKind::BitFlip,
        };
        let via_assignment = CacheKey::for_assignment(
            "mlp3",
            &["mul8s_1kvp_s", "exact", "mul8s_1kvp_s"],
            10,
            20,
            30,
            1,
            Fidelity::FiFull,
        );
        assert_eq!(legacy.to_string_key(), via_assignment.to_string_key());
        // fully exact reduces to the ("exact", 0) key
        let exact =
            CacheKey::for_assignment("mlp3", &["exact"; 3], 10, 20, 30, 1, Fidelity::FiFull);
        assert_eq!(exact.mult, "exact");
        assert_eq!(exact.mask, 0);
        assert!(exact.assignment.is_empty());
    }

    #[test]
    fn fidelity_tiers_render_legacy_and_tagged_keys() {
        let mk = |fid| {
            let mut k = key("mlp3", 1);
            k.fidelity = fid;
            k.to_string_key()
        };
        // the two legacy tiers ARE the historical with_fi bit — untagged
        // pre-ladder entries read back as FiFull / Accuracy
        assert!(mk(Fidelity::FiFull).ends_with("|1"));
        assert!(mk(Fidelity::Accuracy).ends_with("|0"));
        assert!(mk(Fidelity::FiScreen).ends_with("|1|fid:screen"));
        assert!(mk(Fidelity::HwOnly).ends_with("|0|fid:hw"));
        // screen-grade estimates can never shadow full results
        let keys: std::collections::BTreeSet<String> =
            Fidelity::ALL.iter().map(|&f| mk(f)).collect();
        assert_eq!(keys.len(), 4);
    }

    #[test]
    fn legacy_untagged_records_are_served_to_fifull_lookups() {
        // a cache line exactly as PR 1 wrote it: no fidelity tag anywhere
        let dir = std::env::temp_dir().join(format!("deepaxe_cache5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let legacy_line = format!(
            "{{\"key\": \"{}\", \"point\": {}}}\n",
            key("mlp3", 1).to_string_key(),
            point("mlp3", 1).to_json()
        );
        reset(&p);
        std::fs::write(&p, legacy_line).unwrap();
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key("mlp3", 1)).unwrap().mask, 1, "FiFull lookup hits legacy entry");
        let mut screen = key("mlp3", 1);
        screen.fidelity = Fidelity::FiScreen;
        assert!(c.get(&screen).is_none(), "screen lookup must not alias the legacy entry");
    }

    #[test]
    fn fault_models_tag_keys_bitflip_stays_legacy() {
        // BitFlip (the default) renders the exact pre-PR-6 key string;
        // every other model appends an fm: tag, and all four are distinct
        let base = key("mlp3", 1);
        assert_eq!(base.to_string_key(), base.clone().with_fault_model(FaultModelKind::BitFlip).to_string_key());
        assert!(!base.to_string_key().contains("fm:"));
        let stuck = base.clone().with_fault_model(FaultModelKind::StuckAt);
        assert!(stuck.to_string_key().ends_with("|fm:stuckat"), "{}", stuck.to_string_key());
        let keys: std::collections::BTreeSet<String> = FaultModelKind::ALL
            .iter()
            .map(|&fm| base.clone().with_fault_model(fm).to_string_key())
            .collect();
        assert_eq!(keys.len(), 4, "one key per fault model");
        // the tag composes with the cfg: shape too
        let het = CacheKey::for_assignment(
            "mlp3",
            &["mul8s_1kvp_s", "mul8s_1kv8_s", "exact"],
            10,
            20,
            30,
            1,
            Fidelity::FiFull,
        )
        .with_fault_model(FaultModelKind::MultiBit);
        assert!(het.to_string_key().contains("cfg:"));
        assert!(het.to_string_key().ends_with("|fm:multibit"));
    }

    #[test]
    fn pre_pr6_cache_lines_round_trip_as_bitflip() {
        // a cache line byte-for-byte as PR 1 wrote it (no fm: tag, no
        // fidelity tag): a BitFlip FiFull lookup must hit it, and lookups
        // under any other fault model must miss
        let dir = std::env::temp_dir().join(format!("deepaxe_cache6_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let legacy_line = format!(
            "{{\"key\": \"mlp3|exact|1|10|20|30|1|1\", \"point\": {}}}\n",
            point("mlp3", 1).to_json()
        );
        reset(&p);
        std::fs::write(&p, legacy_line).unwrap();
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 1);
        assert_eq!(
            c.get(&key("mlp3", 1)).unwrap().mask,
            1,
            "default (BitFlip) lookup hits the untagged pre-PR-6 record"
        );
        for fm in [FaultModelKind::StuckAt, FaultModelKind::LutPlane, FaultModelKind::MultiBit] {
            let k = key("mlp3", 1).with_fault_model(fm);
            assert!(c.get(&k).is_none(), "{} must not alias the legacy entry", fm.name());
        }
        // and a tagged write round-trips through the file
        let mut c = ResultCache::open(&p);
        let k = key("mlp3", 2).with_fault_model(FaultModelKind::StuckAt);
        c.put(&k, point("mlp3", 2)).unwrap();
        drop(c);
        let c = ResultCache::open(&p);
        assert_eq!(c.get(&k).unwrap().mask, 2);
        assert!(c.get(&key("mlp3", 2)).is_none(), "untagged lookup misses the tagged record");
    }

    #[test]
    fn heterogeneous_assignments_get_distinct_keys() {
        let mk = |names: &[&str]| {
            CacheKey::for_assignment("mlp3", names, 10, 20, 30, 1, Fidelity::FiFull)
                .to_string_key()
        };
        let a = mk(&["mul8s_1kvp_s", "mul8s_1kv8_s", "exact"]);
        let b = mk(&["mul8s_1kv8_s", "mul8s_1kvp_s", "exact"]);
        let hom = mk(&["mul8s_1kvp_s", "mul8s_1kvp_s", "exact"]);
        assert_ne!(a, b, "layer order must matter");
        assert_ne!(a, hom);
        assert!(a.contains("cfg:"), "{a}");
        assert!(!hom.contains("cfg:"), "{hom}");
    }

    #[test]
    fn heterogeneous_roundtrip_persists() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        let k = CacheKey::for_assignment(
            "mlp3",
            &["mul8s_1kvp_s", "mul8s_1kv8_s", "exact"],
            10,
            20,
            30,
            1,
            Fidelity::FiFull,
        );
        {
            let mut c = ResultCache::open(&p);
            c.put(&k, point("mlp3", k.mask)).unwrap();
        }
        let c = ResultCache::open(&p);
        assert_eq!(c.get(&k).unwrap().mask, 0b011);
    }

    #[test]
    fn latest_write_wins() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        let mut c = ResultCache::open(&p);
        c.put(&key("m", 1), point("m", 1)).unwrap();
        let mut p2 = point("m", 1);
        p2.ax_acc = 0.42;
        c.put(&key("m", 1), p2).unwrap();
        drop(c);
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key("m", 1)).unwrap().ax_acc, 0.42);
    }

    #[test]
    fn recovery_report_counts_quarantined_lines() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache7_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let good = |mask| {
            format!(
                "{{\"key\": \"{}\", \"point\": {}}}",
                key("mlp3", mask).to_string_key(),
                point("mlp3", mask).to_json()
            )
        };
        reset(&p);
        std::fs::write(&p, format!("{}\n{{\"key\": \"torn\n{}\n", good(1), good(2))).unwrap();
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 2);
        let r = c.recovery_report();
        assert_eq!((r.lines, r.loaded, r.quarantined), (3, 2, 1));
        assert!(!r.is_clean());
    }

    /// Satellite (c): a crash can truncate a segment at ANY byte of the
    /// final append. Whatever the cut point, load must succeed, quarantine
    /// at most the torn line, serve every complete record — and a compact
    /// pass must round-trip the survivors into a clean segment. Runs on a
    /// single-shard cache so every record shares one segment file.
    #[test]
    fn property_truncation_at_every_offset_is_recoverable() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache8_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        {
            let mut c = ResultCache::open_with_shards(&p, 1);
            for mask in 1..=3 {
                c.put(&key("mlp3", mask), point("mlp3", mask)).unwrap();
            }
        }
        let seg = shard_path(&p, 0);
        let full = std::fs::read(&seg).unwrap();
        // byte length of the first two complete records (incl. newline)
        let text = String::from_utf8(full.clone()).unwrap();
        let mut nl = text.match_indices('\n');
        let keep = nl.nth(1).unwrap().0 + 1;
        // stop before full.len() - 1: cutting only the trailing newline
        // leaves the third record complete, not torn
        for cut in keep..full.len() - 1 {
            // compact (below) merged the previous iteration into the base
            // file and removed the segments; restore the crashed layout
            let _ = std::fs::remove_file(&p);
            std::fs::create_dir_all(shard_dir(&p)).unwrap();
            std::fs::write(&seg, &full[..cut]).unwrap();
            let mut c = ResultCache::open_with_shards(&p, 1);
            let r = c.recovery_report().clone();
            assert_eq!(r.loaded, 2, "cut at byte {cut}: both intact records load");
            assert!(r.quarantined <= 1, "cut at byte {cut}: at most the torn line quarantined");
            assert_eq!(c.get(&key("mlp3", 1)).unwrap().mask, 1);
            assert_eq!(c.get(&key("mlp3", 2)).unwrap().mask, 2);
            assert!(c.get(&key("mlp3", 3)).is_none(), "cut at byte {cut}: torn record must not load");
            // compact → clean base segment, survivors intact
            assert_eq!(c.compact().unwrap(), 2);
            assert!(!p.with_extension("tmp").exists());
            assert!(!seg.exists(), "cut at byte {cut}: compact removes the shard segment");
            let c2 = ResultCache::open_with_shards(&p, 1);
            assert!(c2.recovery_report().is_clean(), "cut at byte {cut}: compacted file is clean");
            assert_eq!(c2.len(), 2);
            assert_eq!(c2.get(&key("mlp3", 2)).unwrap().mask, 2);
        }
    }

    #[test]
    fn compact_preserves_fidelity_and_fault_model_tags() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache9_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        let mut c = ResultCache::open(&p);
        let mut screen = key("mlp3", 1);
        screen.fidelity = Fidelity::FiScreen;
        let tagged = key("mlp3", 2).with_fault_model(FaultModelKind::StuckAt);
        c.put(&screen, point("mlp3", 1)).unwrap();
        c.put(&tagged, point("mlp3", 2)).unwrap();
        c.put(&key("mlp3", 3), point("mlp3", 3)).unwrap();
        c.compact().unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            let k = j.get("key").and_then(|k| k.as_str()).unwrap().to_string();
            let fid = j.get("fidelity").and_then(|f| f.as_str()).unwrap().to_string();
            assert_eq!(fid, fidelity_from_string_key(&k), "compacted fidelity field matches key");
        }
        let c = ResultCache::open(&p);
        assert_eq!(c.get(&screen).unwrap().mask, 1);
        assert_eq!(c.get(&tagged).unwrap().mask, 2);
        assert_eq!(c.get(&key("mlp3", 3)).unwrap().mask, 3);
    }

    #[test]
    fn flush_reports_marks_and_rollback_truncates() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache10_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        let mut c = ResultCache::open_with_shards(&p, 2);
        c.put(&key("m", 1), point("m", 1)).unwrap();
        c.put(&key("m", 2), point("m", 2)).unwrap();
        let mark = c.flush();
        assert_eq!(mark.shards.len(), 2);
        let on_disk: u64 = (0..2).map(|i| file_len(&shard_path(&p, i))).sum();
        assert_eq!(mark.total(), on_disk + file_len(&p));
        c.put(&key("m", 4), point("m", 4)).unwrap();
        assert!(c.flush().total() > mark.total());
        // resume path: discard the post-checkpoint append
        c.rollback_to(&mark).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.get(&key("m", 4)).is_none());
        assert!(c.recovery_report().is_clean(), "rollback lands on a line boundary");
        for (i, &bytes) in mark.shards.iter().enumerate() {
            assert_eq!(file_len(&shard_path(&p, i)), bytes, "shard {i} back at its mark");
        }
        // appends still work after a rollback
        c.put(&key("m", 8), point("m", 8)).unwrap();
        drop(c);
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 3);
        assert_eq!(c.get(&key("m", 8)).unwrap().mask, 8);
        assert_eq!(c.shard_count(), 2, "on-disk layout is sticky over the env default");
    }

    /// Satellite bugfix regression: tear ONE shard mid-record while the
    /// others stay intact. Only that segment may quarantine a line, every
    /// other record must be served, and a legacy (shard-less) mark must
    /// empty every shard segment on rollback.
    #[test]
    fn torn_single_shard_quarantines_only_that_segment() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache12_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        let mut c = ResultCache::open_with_shards(&p, 4);
        for mask in 1..=12 {
            c.put(&key("m", mask), point("m", mask)).unwrap();
        }
        let mark = c.flush();
        drop(c);
        // tear the last record of the fullest segment mid-line
        let (victim, victim_bytes) = mark
            .shards
            .iter()
            .copied()
            .enumerate()
            .max_by_key(|&(_, b)| b)
            .unwrap();
        assert!(victim_bytes > 0, "at least one shard must hold records");
        let seg = shard_path(&p, victim);
        let bytes = std::fs::read(&seg).unwrap();
        let torn: Vec<String> = {
            let text = String::from_utf8(bytes.clone()).unwrap();
            text.lines().map(str::to_string).collect()
        };
        std::fs::write(&seg, &bytes[..bytes.len() - 10]).unwrap();
        let c = ResultCache::open(&p);
        assert_eq!(c.shard_count(), 4);
        assert_eq!(c.recovery_report().quarantined, 1, "exactly the torn line quarantined");
        assert_eq!(c.len(), 11, "every intact record is served");
        // the per-segment report pins the damage to the torn shard
        let dirty: Vec<String> = c
            .segment_reports()
            .into_iter()
            .filter(|(_, r)| !r.is_clean())
            .map(|(name, _)| name)
            .collect();
        assert_eq!(dirty, vec![seg.display().to_string()]);
        // the torn record is the victim segment's last line — all other
        // masks still resolve
        let lost = torn.last().unwrap();
        for mask in 1..=12u64 {
            let hit = c.get(&key("m", mask)).is_some();
            let expect_lost = lost.contains(&key("m", mask).to_string_key());
            assert_eq!(hit, !expect_lost, "mask {mask}");
        }
        // pre-shard journals carry a single byte length: rolling back to
        // a legacy mark must truncate every shard segment to empty
        let mut c = c;
        c.rollback_to(&CacheMark::legacy(0)).unwrap();
        assert!(c.is_empty());
        for i in 0..4 {
            assert_eq!(file_len(&shard_path(&p, i)), 0, "shard {i} emptied by legacy rollback");
        }
    }

    #[test]
    fn compact_merges_segments_into_base_and_marks_collapse() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache13_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        // a legacy base record plus sharded appends over it
        let legacy_line = format!(
            "{{\"key\": \"{}\", \"point\": {}}}\n",
            key("m", 1).to_string_key(),
            point("m", 1).to_json()
        );
        std::fs::write(&p, legacy_line).unwrap();
        let mut c = ResultCache::open_with_shards(&p, 3);
        assert_eq!(c.len(), 1, "legacy single-file cache loads transparently");
        let mut newer = point("m", 1);
        newer.ax_acc = 0.123;
        c.put(&key("m", 1), newer).unwrap();
        c.put(&key("m", 2), point("m", 2)).unwrap();
        assert_eq!(c.get(&key("m", 1)).unwrap().ax_acc, 0.123, "segment overrides base");
        assert_eq!(c.compact().unwrap(), 2);
        assert!(!shard_dir(&p).exists(), "compact removes the segment directory");
        let mark = c.flush();
        assert_eq!(mark.shards.iter().sum::<u64>(), 0, "all bytes live in the base segment");
        assert_eq!(mark.base, file_len(&p));
        let c = ResultCache::open_with_shards(&p, 3);
        assert!(c.recovery_report().is_clean());
        assert_eq!(c.get(&key("m", 1)).unwrap().ax_acc, 0.123);
        assert_eq!(c.get(&key("m", 2)).unwrap().mask, 2);
    }

    #[test]
    fn buffered_appends_become_durable_on_flush() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache11_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        reset(&p);
        let mut c = ResultCache::open(&p);
        c.set_autoflush(false);
        c.put(&key("m", 1), point("m", 1)).unwrap();
        // a small record sits in the BufWriter until flushed
        let on_disk = ResultCache::open(&p);
        assert_eq!(on_disk.len(), 0, "unflushed append must not be visible on disk");
        c.flush();
        let on_disk = ResultCache::open(&p);
        assert_eq!(on_disk.len(), 1, "flush makes the append durable");
        // dropping the cache also drains the buffer (BufWriter flush-on-drop)
        c.put(&key("m", 2), point("m", 2)).unwrap();
        drop(c);
        let on_disk = ResultCache::open(&p);
        assert_eq!(on_disk.len(), 2);
    }
}
