//! Result cache: append-only JSONL of evaluated design points, keyed by
//! (net, mult, mask, evaluation parameters). Lets the coordinator resume
//! interrupted sweeps and share FI results between experiments (Table III
//! rows reuse Fig. 3 sweep points, like the paper's iterative flow).

use super::DesignPoint;
use crate::eval::Fidelity;
use crate::faultsim::FaultModelKind;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Evaluation-parameter fingerprint: results are only reusable when the
/// campaign parameters match.
///
/// Two key shapes share the store: the legacy homogeneous shape
/// `(net, mult, mask)` from the paper's single-AxM sweeps, and the
/// generalized per-layer assignment shape (`assignment` = comma-joined
/// multiplier name per computing layer) used by the `search` subsystem.
/// [`CacheKey::for_assignment`] canonicalizes: any assignment expressible
/// as `(mult, mask)` renders the *legacy* string key, so heterogeneous
/// searches get hits on results that exhaustive sweeps already persisted
/// (and vice versa), and pre-existing cache files stay valid.
///
/// Keys carry the [`Fidelity`] the point was computed at. The two legacy
/// tiers render the historical `|0` / `|1` `with_fi` suffix unchanged —
/// so untagged entries in pre-ladder cache files read back as
/// [`Fidelity::FiFull`] (or [`Fidelity::Accuracy`] for `with_fi = 0`)
/// exactly as they were written — while the new tiers append a `fid:`
/// marker so a screen-grade estimate can never shadow a full result.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CacheKey {
    pub net: String,
    pub mult: String,
    pub mask: u64,
    /// canonical per-layer multiplier names (empty for homogeneous keys,
    /// which use the legacy `(mult, mask)` encoding)
    pub assignment: String,
    pub n_faults: usize,
    pub n_images: usize,
    pub eval_images: usize,
    pub seed: u64,
    /// fidelity tier the cached point was evaluated at
    pub fidelity: Fidelity,
    /// fault model the FI numbers were computed under. [`FaultModelKind::BitFlip`]
    /// (the historical model, and the default) renders *nothing* — every
    /// pre-PR-6 untagged cache line reads back as a BitFlip record — while
    /// the other models append a `fm:` tag so e.g. a stuck-at vulnerability
    /// can never shadow a bit-flip one.
    pub fault_model: FaultModelKind,
}

impl CacheKey {
    /// Canonical key for a per-layer multiplier assignment. Homogeneous
    /// assignments (all non-exact layers share one multiplier, or fully
    /// exact) reduce to the legacy `(net, mult, mask)` key — the
    /// backward-compat path for existing cache files.
    pub fn for_assignment(
        net: &str,
        names: &[&str],
        n_faults: usize,
        n_images: usize,
        eval_images: usize,
        seed: u64,
        fidelity: Fidelity,
    ) -> CacheKey {
        let mut mask = 0u64;
        let mut hom: Option<&str> = None;
        let mut mixed = false;
        for (ci, n) in names.iter().enumerate() {
            if *n != "exact" {
                mask |= 1 << ci;
                match hom {
                    None => hom = Some(n),
                    Some(h) if h != *n => mixed = true,
                    _ => {}
                }
            }
        }
        let (mult, assignment) = if mixed {
            ("mixed".to_string(), names.join(","))
        } else {
            (hom.unwrap_or("exact").to_string(), String::new())
        };
        CacheKey {
            net: net.to_string(),
            mult,
            mask,
            assignment,
            n_faults,
            n_images,
            eval_images,
            seed,
            fidelity,
            fault_model: FaultModelKind::BitFlip,
        }
    }

    /// Same key under a different fault model (builder for zoo campaigns).
    pub fn with_fault_model(mut self, fault_model: FaultModelKind) -> CacheKey {
        self.fault_model = fault_model;
        self
    }

    /// Fidelity rendering: legacy tiers keep the historical `with_fi` bit
    /// verbatim (existing cache files stay valid); ladder-only tiers tag
    /// on a `fid:` marker.
    fn fidelity_suffix(&self) -> &'static str {
        match self.fidelity {
            Fidelity::Accuracy => "0",
            Fidelity::FiFull => "1",
            Fidelity::HwOnly => "0|fid:hw",
            Fidelity::FiScreen => "1|fid:screen",
        }
    }

    /// Fault-model rendering: BitFlip is the untagged legacy encoding.
    fn fault_model_suffix(&self) -> String {
        match self.fault_model {
            FaultModelKind::BitFlip => String::new(),
            other => format!("|fm:{}", other.name()),
        }
    }

    fn to_string_key(&self) -> String {
        if self.assignment.is_empty() {
            format!(
                "{}|{}|{:x}|{}|{}|{}|{}|{}{}",
                self.net,
                self.mult,
                self.mask,
                self.n_faults,
                self.n_images,
                self.eval_images,
                self.seed,
                self.fidelity_suffix(),
                self.fault_model_suffix()
            )
        } else {
            format!(
                "{}|cfg:{}|{}|{}|{}|{}|{}{}",
                self.net,
                self.assignment,
                self.n_faults,
                self.n_images,
                self.eval_images,
                self.seed,
                self.fidelity_suffix(),
                self.fault_model_suffix()
            )
        }
    }
}

pub struct ResultCache {
    path: PathBuf,
    map: BTreeMap<String, DesignPoint>,
}

impl ResultCache {
    /// Load (or start) the cache at `path`. Unparseable lines are skipped
    /// with a warning rather than failing the run.
    pub fn open(path: impl AsRef<Path>) -> ResultCache {
        let path = path.as_ref().to_path_buf();
        let mut map = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            for (ln, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                match Json::parse(line) {
                    Ok(j) => {
                        let key = j.get("key").and_then(|k| k.as_str()).map(str::to_string);
                        let point = j.get("point").and_then(DesignPoint::from_json);
                        match (key, point) {
                            (Some(k), Some(p)) => {
                                map.insert(k, p);
                            }
                            _ => eprintln!("cache {}: line {} malformed, skipped", path.display(), ln + 1),
                        }
                    }
                    Err(e) => {
                        eprintln!("cache {}: line {} unparseable ({e}), skipped", path.display(), ln + 1)
                    }
                }
            }
        }
        ResultCache { path, map }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&self, key: &CacheKey) -> Option<&DesignPoint> {
        self.map.get(&key.to_string_key())
    }

    /// Every cached `(string key, point)` pair, in key order. The string
    /// key layout is documented on [`CacheKey`]; consumers that need the
    /// per-layer assignment back out of a key (e.g. warm-starting a
    /// search from cached frontiers) parse the `cfg:` / legacy segments.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &DesignPoint)> {
        self.map.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Insert + append to the backing file. Records are tagged with the
    /// fidelity they were computed at; pre-ladder readers ignore the extra
    /// field, pre-ladder *writers* never produced it — which is fine,
    /// because their keys only ever encoded the two legacy tiers.
    pub fn put(&mut self, key: &CacheKey, point: DesignPoint) -> std::io::Result<()> {
        let record = json::obj(vec![
            ("key", json::str(key.to_string_key())),
            ("fidelity", json::str(key.fidelity.name())),
            ("point", point.to_json()),
        ]);
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
        writeln!(f, "{record}")?;
        self.map.insert(key.to_string_key(), point);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(net: &str, mask: u64) -> DesignPoint {
        DesignPoint {
            net: net.into(),
            mult: "exact".into(),
            mask,
            config_string: "000".into(),
            base_acc: 0.9,
            ax_acc: 0.9,
            acc_drop_pct: 0.0,
            fi_mean_acc: 0.8,
            fault_vuln_pct: 10.0,
            fi_faults: 10,
            fi_ci95_pp: 0.5,
            cycles: 100,
            luts: 10,
            ffs: 20,
            util_pct: 0.5,
            power_mw: 2.0,
        }
    }

    fn key(net: &str, mask: u64) -> CacheKey {
        CacheKey {
            net: net.into(),
            mult: "exact".into(),
            mask,
            assignment: String::new(),
            n_faults: 10,
            n_images: 20,
            eval_images: 30,
            seed: 1,
            fidelity: Fidelity::FiFull,
            fault_model: FaultModelKind::BitFlip,
        }
    }

    #[test]
    fn put_get_persist() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let _ = std::fs::remove_file(&p);
        {
            let mut c = ResultCache::open(&p);
            assert!(c.is_empty());
            c.put(&key("mlp3", 1), point("mlp3", 1)).unwrap();
            c.put(&key("mlp3", 2), point("mlp3", 2)).unwrap();
            assert_eq!(c.len(), 2);
        }
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&key("mlp3", 1)).unwrap().mask, 1);
        assert!(c.get(&key("mlp3", 3)).is_none());
        // different params -> different key -> miss
        let mut other = key("mlp3", 1);
        other.n_faults = 99;
        assert!(c.get(&other).is_none());
    }

    #[test]
    fn malformed_lines_skipped() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        std::fs::write(&p, "not json\n{\"key\": \"k\"}\n").unwrap();
        let c = ResultCache::open(&p);
        assert!(c.is_empty());
    }

    #[test]
    fn homogeneous_assignment_hits_legacy_keys() {
        // a heterogeneous-genotype lookup whose assignment happens to be
        // homogeneous must produce the exact legacy key string — existing
        // cache files keep working
        let legacy = CacheKey {
            net: "mlp3".into(),
            mult: "mul8s_1kvp_s".into(),
            mask: 0b101,
            assignment: String::new(),
            n_faults: 10,
            n_images: 20,
            eval_images: 30,
            seed: 1,
            fidelity: Fidelity::FiFull,
            fault_model: FaultModelKind::BitFlip,
        };
        let via_assignment = CacheKey::for_assignment(
            "mlp3",
            &["mul8s_1kvp_s", "exact", "mul8s_1kvp_s"],
            10,
            20,
            30,
            1,
            Fidelity::FiFull,
        );
        assert_eq!(legacy.to_string_key(), via_assignment.to_string_key());
        // fully exact reduces to the ("exact", 0) key
        let exact =
            CacheKey::for_assignment("mlp3", &["exact"; 3], 10, 20, 30, 1, Fidelity::FiFull);
        assert_eq!(exact.mult, "exact");
        assert_eq!(exact.mask, 0);
        assert!(exact.assignment.is_empty());
    }

    #[test]
    fn fidelity_tiers_render_legacy_and_tagged_keys() {
        let mk = |fid| {
            let mut k = key("mlp3", 1);
            k.fidelity = fid;
            k.to_string_key()
        };
        // the two legacy tiers ARE the historical with_fi bit — untagged
        // pre-ladder entries read back as FiFull / Accuracy
        assert!(mk(Fidelity::FiFull).ends_with("|1"));
        assert!(mk(Fidelity::Accuracy).ends_with("|0"));
        assert!(mk(Fidelity::FiScreen).ends_with("|1|fid:screen"));
        assert!(mk(Fidelity::HwOnly).ends_with("|0|fid:hw"));
        // screen-grade estimates can never shadow full results
        let keys: std::collections::BTreeSet<String> =
            Fidelity::ALL.iter().map(|&f| mk(f)).collect();
        assert_eq!(keys.len(), 4);
    }

    #[test]
    fn legacy_untagged_records_are_served_to_fifull_lookups() {
        // a cache line exactly as PR 1 wrote it: no fidelity tag anywhere
        let dir = std::env::temp_dir().join(format!("deepaxe_cache5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let legacy_line = format!(
            "{{\"key\": \"{}\", \"point\": {}}}\n",
            key("mlp3", 1).to_string_key(),
            point("mlp3", 1).to_json()
        );
        std::fs::write(&p, legacy_line).unwrap();
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key("mlp3", 1)).unwrap().mask, 1, "FiFull lookup hits legacy entry");
        let mut screen = key("mlp3", 1);
        screen.fidelity = Fidelity::FiScreen;
        assert!(c.get(&screen).is_none(), "screen lookup must not alias the legacy entry");
    }

    #[test]
    fn fault_models_tag_keys_bitflip_stays_legacy() {
        // BitFlip (the default) renders the exact pre-PR-6 key string;
        // every other model appends an fm: tag, and all four are distinct
        let base = key("mlp3", 1);
        assert_eq!(base.to_string_key(), base.clone().with_fault_model(FaultModelKind::BitFlip).to_string_key());
        assert!(!base.to_string_key().contains("fm:"));
        let stuck = base.clone().with_fault_model(FaultModelKind::StuckAt);
        assert!(stuck.to_string_key().ends_with("|fm:stuckat"), "{}", stuck.to_string_key());
        let keys: std::collections::BTreeSet<String> = FaultModelKind::ALL
            .iter()
            .map(|&fm| base.clone().with_fault_model(fm).to_string_key())
            .collect();
        assert_eq!(keys.len(), 4, "one key per fault model");
        // the tag composes with the cfg: shape too
        let het = CacheKey::for_assignment(
            "mlp3",
            &["mul8s_1kvp_s", "mul8s_1kv8_s", "exact"],
            10,
            20,
            30,
            1,
            Fidelity::FiFull,
        )
        .with_fault_model(FaultModelKind::MultiBit);
        assert!(het.to_string_key().contains("cfg:"));
        assert!(het.to_string_key().ends_with("|fm:multibit"));
    }

    #[test]
    fn pre_pr6_cache_lines_round_trip_as_bitflip() {
        // a cache line byte-for-byte as PR 1 wrote it (no fm: tag, no
        // fidelity tag): a BitFlip FiFull lookup must hit it, and lookups
        // under any other fault model must miss
        let dir = std::env::temp_dir().join(format!("deepaxe_cache6_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let legacy_line = format!(
            "{{\"key\": \"mlp3|exact|1|10|20|30|1|1\", \"point\": {}}}\n",
            point("mlp3", 1).to_json()
        );
        std::fs::write(&p, legacy_line).unwrap();
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 1);
        assert_eq!(
            c.get(&key("mlp3", 1)).unwrap().mask,
            1,
            "default (BitFlip) lookup hits the untagged pre-PR-6 record"
        );
        for fm in [FaultModelKind::StuckAt, FaultModelKind::LutPlane, FaultModelKind::MultiBit] {
            let k = key("mlp3", 1).with_fault_model(fm);
            assert!(c.get(&k).is_none(), "{} must not alias the legacy entry", fm.name());
        }
        // and a tagged write round-trips through the file
        let mut c = ResultCache::open(&p);
        let k = key("mlp3", 2).with_fault_model(FaultModelKind::StuckAt);
        c.put(&k, point("mlp3", 2)).unwrap();
        drop(c);
        let c = ResultCache::open(&p);
        assert_eq!(c.get(&k).unwrap().mask, 2);
        assert!(c.get(&key("mlp3", 2)).is_none(), "untagged lookup misses the tagged record");
    }

    #[test]
    fn heterogeneous_assignments_get_distinct_keys() {
        let mk = |names: &[&str]| {
            CacheKey::for_assignment("mlp3", names, 10, 20, 30, 1, Fidelity::FiFull)
                .to_string_key()
        };
        let a = mk(&["mul8s_1kvp_s", "mul8s_1kv8_s", "exact"]);
        let b = mk(&["mul8s_1kv8_s", "mul8s_1kvp_s", "exact"]);
        let hom = mk(&["mul8s_1kvp_s", "mul8s_1kvp_s", "exact"]);
        assert_ne!(a, b, "layer order must matter");
        assert_ne!(a, hom);
        assert!(a.contains("cfg:"), "{a}");
        assert!(!hom.contains("cfg:"), "{hom}");
    }

    #[test]
    fn heterogeneous_roundtrip_persists() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache4_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let _ = std::fs::remove_file(&p);
        let k = CacheKey::for_assignment(
            "mlp3",
            &["mul8s_1kvp_s", "mul8s_1kv8_s", "exact"],
            10,
            20,
            30,
            1,
            Fidelity::FiFull,
        );
        {
            let mut c = ResultCache::open(&p);
            c.put(&k, point("mlp3", k.mask)).unwrap();
        }
        let c = ResultCache::open(&p);
        assert_eq!(c.get(&k).unwrap().mask, 0b011);
    }

    #[test]
    fn latest_write_wins() {
        let dir = std::env::temp_dir().join(format!("deepaxe_cache3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.jsonl");
        let _ = std::fs::remove_file(&p);
        let mut c = ResultCache::open(&p);
        c.put(&key("m", 1), point("m", 1)).unwrap();
        let mut p2 = point("m", 1);
        p2.ax_acc = 0.42;
        c.put(&key("m", 1), p2).unwrap();
        drop(c);
        let c = ResultCache::open(&p);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key("m", 1)).unwrap().ax_acc, 0.42);
    }
}
