#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # DeepAxe — approximation/reliability DSE for DNN accelerators
//!
//! Rust reproduction of *"DeepAxe: A Framework for Exploration of
//! Approximation and Reliability Trade-offs in DNN Accelerators"*
//! (Taheri, Riazati et al., ISQED 2023), built as the Layer-3 coordinator
//! of a three-layer rust + JAX + Pallas stack (see DESIGN.md).
//!
//! The crate is organized bottom-up:
//!
//! * [`util`] — infrastructure substrates the offline image lacks crates
//!   for: JSON, deterministic RNG, CLI parsing, a worker thread pool,
//!   statistics, a micro-bench harness and a mini property-test framework.
//! * [`nbin`] — the named-tensor container shared with the python build
//!   path (`python/compile/nbin.py`).
//! * [`tensor`] — minimal dense tensors for the integer inference engine.
//! * [`axmul`] — the approximate-multiplier library (EvoApproxLib
//!   stand-in): LUT generators, exhaustive error metrics, catalog.
//! * [`dataset`] — quantized test-set loading.
//! * [`simnet`] — the quantized int8 inference engine (the paper's
//!   generated-C-model analog); every multiply is a LUT lookup, every
//!   activation is a fault-injection site.
//! * [`faultsim`] — single-bit-flip fault model, statistical sample
//!   sizing, campaign runner.
//! * [`hwmodel`] — analytic Vivado-HLS/Spartan-7 cost model (latency
//!   cycles, LUT/FF utilization).
//! * [`dse`] — configuration space, evaluation orchestration, Pareto
//!   frontier and hypervolume indicator.
//! * [`eval`] — the staged multi-fidelity evaluation engine: a
//!   `HwOnly → Accuracy → FiScreen → FiFull` ladder with one shared
//!   fault-site sample per run, block-wise CI-gated campaigns and a
//!   process-wide worker budget; the search stack's hot path.
//! * [`search`] — scalable multi-objective DSE (NSGA-II, simulated
//!   annealing, hill-climb) over heterogeneous per-layer multiplier
//!   assignments; replaces the `2^n` enumeration with budgeted search so
//!   deep-net workloads the exhaustive sweep can never touch become
//!   tractable.
//! * [`recovery`] — crash-safe search runtime: deterministic run-ids,
//!   an atomically-rewritten run journal with checkpoint/replay resume,
//!   and the state hooks the staged evaluator checkpoints through.
//! * [`serve`] — DSE-as-a-service: the `repro serve` job-queue daemon
//!   (Unix-socket JSON protocol, concurrent journaled campaigns),
//!   deterministic search-space partitioning for `repro worker --shard
//!   i/N`, and the `repro merge` multi-process frontier merge.
//! * [`zoo`] — parametric model zoo + synthetic workload generator:
//!   topology grammar, seeded weight synthesis with calibrated
//!   quantization, teacher-labeled datasets — deep nets and their
//!   workloads as pure functions of `(spec, seed)`, no artifacts needed.
//! * [`runtime`] — PJRT executor for the AOT-lowered L2+L1 graphs.
//! * [`coordinator`] — the tool-chain pipeline (Fig. 1/2 of the paper),
//!   job scheduling, result caching, CLI entry points.
//! * [`report`] — regenerates every paper table and figure.

pub mod axmul;
pub mod coordinator;
pub mod dataset;
pub mod dse;
pub mod eval;
pub mod faultsim;
pub mod hwmodel;
pub mod nbin;
pub mod recovery;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod simnet;
pub mod tensor;
pub mod util;
pub mod zoo;

/// Locate the artifacts directory: `$DEEPAXE_ARTIFACTS` or `./artifacts`
/// (walking up from the current dir so tests work from any cwd).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("DEEPAXE_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
