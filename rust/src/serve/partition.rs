//! Deterministic genotype-space partitioning.
//!
//! A [`SearchSpace`] enumerates genotypes with an odometer that increments
//! position 0 first ([`SearchSpace::enumerate_first`]), so position 0 is
//! the *least-significant* digit of a mixed-radix number. That gives every
//! genotype a canonical index
//!
//! ```text
//! index(g) = Σ_i g[i] · Π_{j<i} radix(j)          (0 ≤ index < size)
//! ```
//!
//! and the space a total order that is stable across processes, machines,
//! and runs. [`partition`] cuts `[0, size)` into `n` contiguous, disjoint,
//! fully-covering [`Region`]s along that order; concatenating the regions'
//! enumerations in shard order reproduces `enumerate_first(size)` exactly,
//! which is what makes shard-then-merge bit-identical to a single-process
//! exhaustive run (see [`crate::serve::merge`]).

use crate::search::{Genotype, SearchSpace};

/// A contiguous half-open slice `[start, end)` of the canonical genotype
/// index space, tagged with its shard position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    /// Shard index, `0..of`.
    pub shard: usize,
    /// Total shard count the space was partitioned into.
    pub of: usize,
    /// First canonical index in the region (inclusive).
    pub start: u128,
    /// One past the last canonical index (exclusive); `end - start` is the
    /// region size, possibly 0 when there are more shards than genotypes.
    pub end: u128,
}

impl Region {
    /// Number of genotypes in the region.
    pub fn len(&self) -> u128 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// `"i/N start..end"` — the canonical display used by `repro worker`
    /// logs and shard-archive metadata.
    pub fn label(&self) -> String {
        format!("{}/{} {}..{}", self.shard, self.of, self.start, self.end)
    }
}

/// Guard against saturated [`SearchSpace::size`]: index arithmetic is only
/// meaningful when the true size fits in a `u128`.
fn exact_size(space: &SearchSpace) -> u128 {
    let size = space.size();
    assert!(
        size < u128::MAX,
        "partition: space size saturates u128 — cannot index genotypes canonically"
    );
    size
}

/// Canonical mixed-radix index of `g` (position 0 least significant).
pub fn canonical_index(space: &SearchSpace, g: &Genotype) -> u128 {
    assert_eq!(g.len(), space.genotype_len(), "genotype length mismatch");
    exact_size(space);
    let mut idx: u128 = 0;
    for i in (0..g.len()).rev() {
        let r = space.radix(i) as u128;
        debug_assert!((g[i] as u128) < r, "digit {} out of radix at position {i}", g[i]);
        idx = idx * r + g[i] as u128;
    }
    idx
}

/// Genotype at canonical index `idx` — inverse of [`canonical_index`].
pub fn genotype_at(space: &SearchSpace, idx: u128) -> Genotype {
    assert!(idx < exact_size(space), "index {idx} out of range");
    let mut rest = idx;
    let mut g = vec![0u8; space.genotype_len()];
    for (i, d) in g.iter_mut().enumerate() {
        let r = space.radix(i) as u128;
        *d = (rest % r) as u8;
        rest /= r;
    }
    debug_assert_eq!(rest, 0);
    g
}

/// Split the space into `n` contiguous regions: disjoint, fully covering,
/// in shard order. The first `size % n` regions get one extra genotype
/// (ragged split), so region sizes differ by at most 1; when `n > size`
/// the tail regions are empty. Deterministic — every caller that asks for
/// the same `(space, n)` gets the same cut, which is what lets independent
/// worker processes agree on who owns what without coordination.
pub fn partition(space: &SearchSpace, n: usize) -> Vec<Region> {
    assert!(n >= 1, "partition: need at least one shard");
    let size = exact_size(space);
    let base = size / n as u128;
    let rem = size % n as u128;
    let mut regions = Vec::with_capacity(n);
    let mut cursor: u128 = 0;
    for shard in 0..n {
        let len = base + u128::from((shard as u128) < rem);
        regions.push(Region { shard, of: n, start: cursor, end: cursor + len });
        cursor += len;
    }
    debug_assert_eq!(cursor, size);
    regions
}

/// Enumerate a region's genotypes in canonical order. Seeds the odometer
/// at `region.start` and rolls it forward, so the cost is O(len · digits)
/// just like [`SearchSpace::enumerate_first`] — no per-genotype division
/// chain beyond the first.
pub fn enumerate_region(space: &SearchSpace, region: &Region) -> Vec<Genotype> {
    assert!(region.end <= exact_size(space), "region exceeds space");
    if region.is_empty() {
        return Vec::new();
    }
    let len = usize::try_from(region.len()).expect("region too large to materialize");
    let mut out = Vec::with_capacity(len);
    let mut g = genotype_at(space, region.start);
    for produced in 0..len {
        out.push(g.clone());
        if produced + 1 < len {
            advance(space, &mut g);
        }
    }
    out
}

/// Odometer step matching [`SearchSpace::enumerate_first`]: increment
/// position 0, carrying right.
pub(crate) fn advance(space: &SearchSpace, g: &mut Genotype) {
    for i in 0..g.len() {
        g[i] += 1;
        if (g[i] as u64) < space.radix(i) {
            return;
        }
        g[i] = 0;
    }
    panic!("advance: odometer overflow past end of space");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn space(n_layers: usize, symbols: usize, hardening: bool) -> SearchSpace {
        let alphabet: Vec<String> = (0..symbols)
            .map(|i| if i == 0 { "exact".into() } else { format!("ax{i}") })
            .collect();
        let s = SearchSpace::with_dims("t", n_layers, alphabet, &"x".repeat(n_layers));
        if hardening {
            s.with_hardening()
        } else {
            s
        }
    }

    #[test]
    fn index_matches_enumeration_order() {
        let s = space(3, 3, false);
        let all = s.enumerate_first(s.size() as usize);
        for (i, g) in all.iter().enumerate() {
            assert_eq!(canonical_index(&s, g), i as u128);
            assert_eq!(genotype_at(&s, i as u128), *g);
        }
    }

    #[test]
    fn partition_ragged_covers_exactly() {
        // N not dividing size, N > size, N = 1 — the ISSUE's ragged cases.
        let s = space(2, 3, false); // size 9
        for n in [1usize, 2, 4, 9, 13] {
            let regions = partition(&s, n);
            assert_eq!(regions.len(), n);
            assert_eq!(regions[0].start, 0);
            assert_eq!(regions[n - 1].end, s.size());
            for w in regions.windows(2) {
                assert_eq!(w[0].end, w[1].start, "regions must chain without gaps");
            }
            let sizes: Vec<u128> = regions.iter().map(|r| r.len()).collect();
            let (min, max) =
                (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "ragged split must differ by at most 1");
            let concat: Vec<Genotype> =
                regions.iter().flat_map(|r| enumerate_region(&s, r)).collect();
            assert_eq!(concat, s.enumerate_first(s.size() as usize));
        }
    }

    #[test]
    fn partition_more_shards_than_genotypes() {
        let s = space(1, 2, false); // size 2
        let regions = partition(&s, 5);
        let nonempty: Vec<&Region> = regions.iter().filter(|r| !r.is_empty()).collect();
        assert_eq!(nonempty.len(), 2);
        assert!(regions[2..].iter().all(|r| r.is_empty()));
        assert_eq!(regions[4].end, s.size());
    }

    #[test]
    fn prop_index_roundtrip() {
        check("partition_index_roundtrip", 0xC0DE, 200, |rng| {
            let n_layers = 1 + rng.usize_below(5);
            let symbols = 2 + rng.usize_below(4);
            let s = space(n_layers, symbols, rng.below(2) == 0);
            let idx = rng.below(s.size() as u64) as u128;
            let g = genotype_at(&s, idx);
            assert_eq!(g.len(), s.genotype_len());
            assert_eq!(canonical_index(&s, &g), idx);
        });
    }

    #[test]
    fn prop_partition_disjoint_union() {
        check("partition_disjoint_union", 0xD15C, 120, |rng| {
            let n_layers = 1 + rng.usize_below(4);
            let symbols = 2 + rng.usize_below(3);
            let s = space(n_layers, symbols, false);
            let size = s.size();
            let n = 1 + rng.usize_below((size as usize) + 4);
            let regions = partition(&s, n);
            // disjoint + covering: the chained boundaries tile [0, size)
            let mut cursor = 0u128;
            for r in &regions {
                assert_eq!(r.start, cursor);
                assert!(r.end >= r.start);
                cursor = r.end;
            }
            assert_eq!(cursor, size);
            // concatenated enumeration is the canonical enumeration
            let concat: Vec<Genotype> =
                regions.iter().flat_map(|r| enumerate_region(&s, r)).collect();
            assert_eq!(concat, s.enumerate_first(size as usize));
        });
    }

    #[test]
    fn hardening_digits_roundtrip_through_config_string() {
        // canonical index → genotype → digits → genotype survives the
        // hardened space where the second digit block has radix 3
        let s = space(2, 4, true); // 4^2 · 3^2 = 144
        for idx in [0u128, 1, 47, 95, 143] {
            let g = genotype_at(&s, idx);
            let cfg = s.config_digits(&g);
            assert_eq!(s.parse_digits(&cfg).expect("parse"), g);
        }
    }
}
