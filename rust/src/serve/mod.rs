//! serve — DSE-as-a-service: job-queue daemon, deterministic space
//! partitioning, multi-process frontier merge.
//!
//! Three ways to run a search campaign beyond the one-shot CLI:
//!
//! * [`daemon`] — `repro serve`: a Unix-socket daemon accepting
//!   line-delimited JSON jobs ([`protocol`]), multiplexing up to
//!   `max_jobs` concurrent campaigns over the shared
//!   [`crate::util::threadpool::WorkerBudget`]. Live campaigns expose
//!   `status` / `snapshot` / `cancel`; every served campaign writes the
//!   same journal a CLI run would, so it resumes identically.
//! * [`partition`] — deterministic space splitting: the canonical
//!   genotype index maps a [`crate::search::SearchSpace`] onto
//!   `0..size`, and [`partition::partition`] cuts that range into N
//!   disjoint, fully-covering contiguous regions. `repro worker
//!   --shard i/N` ([`worker`]) sweeps one region against its own
//!   journal and cache shard.
//! * [`merge`] — `repro merge`: folds N per-shard archives through
//!   [`crate::dse::pareto`] into a single frontier with merged
//!   [`crate::eval::LedgerSnapshot`] accounting. Because shard regions
//!   concatenate back into enumeration order, the merged frontier,
//!   hypervolumes, and summed counters are bit-identical to a
//!   single-process exhaustive run over the same space.

pub mod daemon;
pub mod merge;
pub mod partition;
pub mod protocol;
pub mod worker;

pub use daemon::{Daemon, JobSpec, ServeConfig};
pub use merge::{merge_archives, Merged, ShardArchive};
pub use partition::{canonical_index, enumerate_region, genotype_at, partition, Region};
pub use protocol::Request;
pub use worker::{run_shard, worker_fingerprint, ShardSpec, WORKER_CHUNK};
