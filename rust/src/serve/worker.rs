//! `repro worker --shard i/N` — one process's exhaustive sweep of its
//! partition region.
//!
//! The worker is deliberately *not* a strategy: it owns a contiguous
//! canonical-index range ([`super::partition`]) and evaluates every
//! genotype in it, in order, against its own result-cache shard and its
//! own run journal. That makes the multi-process story composable from
//! pieces that already exist:
//!
//! * dedup/persistence is the ordinary [`CacheHook`] (each worker points
//!   at its own cache file, so no cross-process locking is needed);
//! * crash safety is the ordinary [`RunJournal`] — the sweep offers a
//!   checkpoint every [`WORKER_CHUNK`] genotypes and replays recorded
//!   events with the backend *and* cache bypassed, exactly like the
//!   search driver's replay path;
//! * the output is a [`ShardArchive`] that `repro merge` folds back into
//!   the single-process result bit-for-bit.

use crate::eval::Fidelity;
use crate::recovery::{Replayed, RunCounters, RunJournal};
use crate::search::{CacheHook, EvalBackend, SearchSpace};
use crate::util::threadpool::catch_retry;

use super::merge::ShardArchive;
use super::partition::{advance, genotype_at, partition, Region};

/// Genotypes between journal boundaries. Matches the driver's exhaustive
/// chunk floor so worker checkpoints land at the same cadence.
pub const WORKER_CHUNK: usize = 64;

/// A `--shard i/N` argument: 0-based shard `index` out of `of` total.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub of: usize,
}

impl ShardSpec {
    /// Parse `"i/N"` with `0 <= i < N`, `N >= 1`.
    pub fn parse(s: &str) -> Result<ShardSpec, String> {
        let (i, n) = s.split_once('/').ok_or_else(|| format!("--shard {s:?}: want i/N"))?;
        let index: usize =
            i.trim().parse().map_err(|_| format!("--shard {s:?}: bad shard index"))?;
        let of: usize = n.trim().parse().map_err(|_| format!("--shard {s:?}: bad shard count"))?;
        if of == 0 {
            return Err(format!("--shard {s:?}: shard count must be >= 1"));
        }
        if index >= of {
            return Err(format!("--shard {s:?}: index {index} out of range 0..{of}"));
        }
        Ok(ShardSpec { index, of })
    }

    /// This shard's region of `space` under the canonical N-way cut.
    pub fn region(&self, space: &SearchSpace) -> Region {
        partition(space, self.of)[self.index]
    }
}

/// Journal fingerprint for a shard sweep: the base campaign fingerprint
/// (net, fault campaign, fidelity — whatever the caller already computes
/// for `repro search`) extended with the shard identity, so a worker can
/// only resume a journal written for the *same* region of the same cut.
pub fn worker_fingerprint(base: &str, region: &Region) -> String {
    format!(
        "{base} kind=shard shard={}/{} range={}..{}",
        region.shard, region.of, region.start, region.end
    )
}

/// Sweep this shard's region. The caller owns journal creation/resume
/// (same contract as `run_search_journaled`): pass [`crate::recovery::NoJournal`]
/// for an unjournaled sweep. The returned archive has an empty ledger —
/// the caller snapshots its staged evaluator into `archive.ledger` (the
/// sweep cannot see through the generic backend).
pub fn run_shard<B: EvalBackend>(
    space: &SearchSpace,
    shard: ShardSpec,
    with_fi: bool,
    backend: &B,
    cache: &mut dyn CacheHook,
    journal: &mut dyn RunJournal,
) -> ShardArchive {
    let region = shard.region(space);
    let fidelity = if with_fi { Fidelity::FiFull } else { Fidelity::Accuracy };
    let len = usize::try_from(region.len()).expect("shard region too large for one process");

    let mut points = Vec::with_capacity(len);
    let mut poisoned: Vec<(String, String)> = Vec::new();
    let mut evals_used = 0usize;
    let mut cache_hits = 0usize;

    let mut g = if region.is_empty() { Vec::new() } else { genotype_at(space, region.start) };
    for done in 0..len {
        let cfg = space.config_digits(&g);
        if journal.replaying() {
            // replay bypasses backend *and* cache: the cache file was
            // rolled back to the checkpoint mark, so re-getting would
            // turn rolled-forward misses into phantom hits
            match journal.replay_eval(&cfg, fidelity) {
                Replayed::Point { hit, point } => {
                    if hit {
                        cache_hits += 1;
                    }
                    evals_used += 1;
                    points.push(point);
                }
                Replayed::Poisoned(err) => poisoned.push((cfg, err)),
            }
        } else {
            let names = space.decode(&g);
            if let Some(p) = cache.get(&names, fidelity) {
                cache_hits += 1;
                evals_used += 1;
                journal.record_eval(&cfg, fidelity, true, &p);
                points.push(p);
            } else {
                match catch_retry(|| backend.eval(&names, fidelity)) {
                    Ok(mut p) => {
                        // store the digit config before the cache sees the
                        // point — same ordering as the driver, so shard
                        // cache files are line-identical to driver ones
                        p.config_string = cfg.clone();
                        cache.put(&names, fidelity, &p);
                        evals_used += 1;
                        journal.record_eval(&cfg, fidelity, false, &p);
                        points.push(p);
                    }
                    Err(err) => {
                        eprintln!("worker: genotype {cfg} panicked twice; quarantined ({err})");
                        journal.record_poison(&cfg, fidelity, &err);
                        poisoned.push((cfg, err));
                    }
                }
            }
        }
        if (done + 1) % WORKER_CHUNK == 0 || done + 1 == len {
            let counters = RunCounters {
                evals_used,
                cache_hits,
                promotions: 0,
                archive_len: points.len(),
                rng_state: None,
            };
            if journal.boundary(&counters) {
                let mark = cache.flush();
                journal.commit_checkpoint(&counters, &mark);
            }
        }
        if done + 1 < len {
            advance(space, &mut g);
        }
    }

    ShardArchive {
        net: space.net.clone(),
        alphabet: space.alphabet.clone(),
        n_layers: space.n_layers,
        template: space.template.clone(),
        hardening: space.hardening,
        region,
        space_size: space.size(),
        with_fi,
        evals_used,
        cache_hits,
        points,
        poisoned,
        ledger: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_spec_parse() {
        assert_eq!(ShardSpec::parse("0/4").unwrap(), ShardSpec { index: 0, of: 4 });
        assert_eq!(ShardSpec::parse("3/4").unwrap(), ShardSpec { index: 3, of: 4 });
        assert!(ShardSpec::parse("4/4").is_err());
        assert!(ShardSpec::parse("1").is_err());
        assert!(ShardSpec::parse("a/4").is_err());
        assert!(ShardSpec::parse("0/0").is_err());
    }
}
