//! Line-delimited JSON protocol between `repro serve` and its clients.
//!
//! One request per line, one response per line, over a Unix domain
//! socket. Requests are objects with an `"op"` discriminant; responses
//! always carry `"ok"` (`true`/`false`), with the error message under
//! `"error"` on failure. The framing is deliberately dumb — any shell
//! with `nc -U` (or a five-line Python client) can drive the daemon.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

use crate::util::json::{self, Json};

/// Env knob for the daemon socket path (`DEEPAXE_SERVE_SOCKET`);
/// defaults to `results/serve.sock`.
pub const SOCKET_ENV: &str = "DEEPAXE_SERVE_SOCKET";
pub const DEFAULT_SOCKET: &str = "results/serve.sock";

/// Env knob for the number of concurrently running campaigns
/// (`DEEPAXE_SERVE_MAX_JOBS`); defaults to [`DEFAULT_MAX_JOBS`].
pub const MAX_JOBS_ENV: &str = "DEEPAXE_SERVE_MAX_JOBS";
pub const DEFAULT_MAX_JOBS: usize = 2;

/// A client request. `Submit` carries the raw job object — the daemon
/// parses it into a `JobSpec` so schema errors come back over the wire
/// instead of killing the connection.
#[derive(Debug, Clone)]
pub enum Request {
    /// Enqueue a search campaign; responds with the assigned job id.
    Submit { job: Json },
    /// One job's state, or all jobs when `job` is `None`.
    Status { job: Option<u64> },
    /// Checkpoint/journal snapshot of a job's run (rides the run journal,
    /// so it reports exactly what a crash would resume from).
    Snapshot { job: u64 },
    /// Cancel a queued job immediately, or a running job at its next
    /// checkpoint boundary.
    Cancel { job: u64 },
    /// Stop accepting requests, finish running jobs, exit.
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit { job } => {
                json::obj(vec![("op", json::str("submit")), ("job", job.clone())])
            }
            Request::Status { job } => {
                let mut pairs = vec![("op", json::str("status"))];
                if let Some(id) = job {
                    pairs.push(("job", json::num(*id as f64)));
                }
                json::obj(pairs)
            }
            Request::Snapshot { job } => {
                json::obj(vec![("op", json::str("snapshot")), ("job", json::num(*job as f64))])
            }
            Request::Cancel { job } => {
                json::obj(vec![("op", json::str("cancel")), ("job", json::num(*job as f64))])
            }
            Request::Shutdown => json::obj(vec![("op", json::str("shutdown"))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        let op = j.get("op").and_then(Json::as_str).ok_or("request missing \"op\"")?;
        let job_id = || {
            j.get("job")
                .and_then(Json::as_i64)
                .map(|v| v as u64)
                .ok_or_else(|| format!("op {op:?} needs a numeric \"job\""))
        };
        match op {
            "submit" => {
                let job = j.get("job").cloned().ok_or("submit needs a \"job\" object")?;
                Ok(Request::Submit { job })
            }
            "status" => {
                Ok(Request::Status { job: j.get("job").and_then(Json::as_i64).map(|v| v as u64) })
            }
            "snapshot" => Ok(Request::Snapshot { job: job_id()? }),
            "cancel" => Ok(Request::Cancel { job: job_id()? }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

/// Success response with extra fields.
pub fn ok(mut fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.append(&mut fields);
    json::obj(pairs)
}

/// Failure response.
pub fn err(msg: impl Into<String>) -> Json {
    json::obj(vec![("ok", Json::Bool(false)), ("error", json::str(msg.into()))])
}

/// Write one protocol line.
pub fn write_line(w: &mut impl Write, j: &Json) -> std::io::Result<()> {
    writeln!(w, "{j}")?;
    w.flush()
}

/// Read one protocol line; `Ok(None)` on clean EOF.
pub fn read_line(r: &mut impl BufRead) -> std::io::Result<Option<Json>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    Json::parse(line.trim())
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

/// One-shot client call: connect, send, await the response.
pub fn call(socket: &Path, req: &Request) -> Result<Json, String> {
    let stream = UnixStream::connect(socket)
        .map_err(|e| format!("connect {}: {e}", socket.display()))?;
    let mut writer = stream.try_clone().map_err(|e| format!("socket clone: {e}"))?;
    write_line(&mut writer, &req.to_json()).map_err(|e| format!("send: {e}"))?;
    let mut reader = BufReader::new(stream);
    match read_line(&mut reader).map_err(|e| format!("recv: {e}"))? {
        Some(resp) => Ok(resp),
        None => Err("daemon closed the connection without responding".into()),
    }
}

/// `true` iff a response object reports success.
pub fn is_ok(resp: &Json) -> bool {
    resp.get("ok").and_then(Json::as_bool) == Some(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::Submit { job: json::obj(vec![("net", json::str("zoo-tiny"))]) },
            Request::Status { job: None },
            Request::Status { job: Some(3) },
            Request::Snapshot { job: 7 },
            Request::Cancel { job: 1 },
            Request::Shutdown,
        ];
        for r in reqs {
            let j = r.to_json();
            let back = Request::from_json(&j).expect("roundtrip");
            assert_eq!(format!("{}", back.to_json()), format!("{j}"));
        }
    }

    #[test]
    fn malformed_requests_are_errors() {
        assert!(Request::from_json(&Json::parse(r#"{"op":"warp"}"#).unwrap()).is_err());
        assert!(Request::from_json(&Json::parse(r#"{"op":"cancel"}"#).unwrap()).is_err());
        assert!(Request::from_json(&Json::parse(r#"{"job":1}"#).unwrap()).is_err());
        assert!(Request::from_json(&Json::parse(r#"{"op":"submit"}"#).unwrap()).is_err());
    }

    #[test]
    fn ok_and_err_shapes() {
        let o = ok(vec![("job", json::num(4.0))]);
        assert!(is_ok(&o));
        assert_eq!(o.get("job").and_then(Json::as_i64), Some(4));
        let e = err("nope");
        assert!(!is_ok(&e));
        assert_eq!(e.get("error").and_then(Json::as_str), Some("nope"));
    }
}
