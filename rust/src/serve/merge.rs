//! Shard archives and the multi-process frontier merge.
//!
//! A `repro worker --shard i/N` run exhausts one [`partition`] region and
//! writes a [`ShardArchive`]: the region metadata, every evaluated
//! [`DesignPoint`] in canonical order, the budget counters, and the
//! worker's [`LedgerSnapshot`]. `repro merge` ([`merge_archives`]) folds N
//! such archives back into one result:
//!
//! * **validation** — all archives must describe the same space and the
//!   same N-way cut, each shard exactly once, regions chaining gaplessly
//!   over `[0, size)`; a missing or duplicated shard is an error, not a
//!   silently smaller frontier.
//! * **concatenation** — points are joined in shard order, which by the
//!   [`partition`] invariant *is* the single-process enumeration order, so
//!   the merged archive is bit-identical (frontier indices, hypervolume
//!   2-D/3-D, budget counters) to one process sweeping the whole space.
//! * **accounting** — per-shard `FiLedger` snapshots sum into one ledger
//!   ([`LedgerSnapshot::merge`]); with no cross-shard evaluator state
//!   (trace cache off, screening off) the sum equals the single-process
//!   ledger exactly.
//!
//! [`partition`]: crate::serve::partition::partition

use std::path::Path;

use crate::dse::pareto::pareto_merge;
use crate::dse::DesignPoint;
use crate::eval::LedgerSnapshot;
use crate::recovery::atomic_write;
use crate::search::{frontier_hv, hypervolume3};
use crate::util::json::{self, Json};

use super::partition::Region;

/// One worker's exhaustive sweep of its partition region, serializable as
/// a single JSON document (written via [`atomic_write`], so a crashed
/// worker never leaves a truncated archive behind).
#[derive(Debug, Clone)]
pub struct ShardArchive {
    /// Net name — merge refuses to mix archives from different nets.
    pub net: String,
    /// Multiplier alphabet of the space (order matters: it defines the
    /// genotype radices and therefore the canonical index).
    pub alphabet: Vec<String>,
    pub n_layers: usize,
    pub template: String,
    pub hardening: bool,
    /// The region this shard owned.
    pub region: Region,
    /// Total space size — redundant with the space dims, kept as a cheap
    /// cross-check that all shards agreed on the cut.
    pub space_size: u128,
    pub with_fi: bool,
    /// Unique genotypes charged against the budget (hit or fresh).
    pub evals_used: usize,
    /// Of those, how many were served by the result cache.
    pub cache_hits: usize,
    /// Evaluated points in canonical region order, `config_string` set.
    pub points: Vec<DesignPoint>,
    /// Quarantined genotypes: `(config_digits, error)`.
    pub poisoned: Vec<(String, String)>,
    /// The worker's FI ledger at the end of the sweep.
    pub ledger: LedgerSnapshot,
}

impl ShardArchive {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("kind", json::str("deepaxe_shard_archive")),
            ("version", json::num(1.0)),
            ("net", json::str(&self.net)),
            (
                "alphabet",
                Json::Arr(self.alphabet.iter().map(json::str).collect()),
            ),
            ("n_layers", json::num(self.n_layers as f64)),
            ("template", json::str(&self.template)),
            ("hardening", Json::Bool(self.hardening)),
            ("shard", json::num(self.region.shard as f64)),
            ("of", json::num(self.region.of as f64)),
            // u128 range bounds as decimal strings: JSON numbers are f64
            ("start", json::str(self.region.start.to_string())),
            ("end", json::str(self.region.end.to_string())),
            ("space_size", json::str(self.space_size.to_string())),
            ("with_fi", Json::Bool(self.with_fi)),
            ("evals_used", json::num(self.evals_used as f64)),
            ("cache_hits", json::num(self.cache_hits as f64)),
            (
                "points",
                Json::Arr(self.points.iter().map(DesignPoint::to_json).collect()),
            ),
            (
                "poisoned",
                Json::Arr(
                    self.poisoned
                        .iter()
                        .map(|(cfg, err)| {
                            json::obj(vec![("config", json::str(cfg)), ("error", json::str(err))])
                        })
                        .collect(),
                ),
            ),
            ("ledger", self.ledger.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardArchive, String> {
        let want = |k: &str| j.get(k).ok_or_else(|| format!("shard archive missing {k:?}"));
        if want("kind")?.as_str() != Some("deepaxe_shard_archive") {
            return Err("not a deepaxe shard archive".into());
        }
        let u128_field = |k: &str| -> Result<u128, String> {
            want(k)?
                .as_str()
                .and_then(|s| s.parse::<u128>().ok())
                .ok_or_else(|| format!("shard archive field {k:?} is not a decimal u128"))
        };
        let usize_field = |k: &str| -> Result<usize, String> {
            want(k)?.as_usize().ok_or_else(|| format!("shard archive field {k:?} is not a count"))
        };
        let points = want("points")?
            .as_arr()
            .ok_or("points is not an array")?
            .iter()
            .map(|p| DesignPoint::from_json(p).ok_or("malformed design point".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        let poisoned = match j.get("poisoned").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|e| {
                    Some((
                        e.get("config")?.as_str()?.to_string(),
                        e.get("error")?.as_str()?.to_string(),
                    ))
                })
                .collect::<Option<Vec<_>>>()
                .ok_or("malformed poisoned entry")?,
            None => Vec::new(),
        };
        let alphabet = want("alphabet")?
            .as_arr()
            .ok_or("alphabet is not an array")?
            .iter()
            .map(|s| s.as_str().map(str::to_string).ok_or("alphabet symbol is not a string"))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ShardArchive {
            net: want("net")?.as_str().ok_or("net is not a string")?.to_string(),
            alphabet,
            n_layers: usize_field("n_layers")?,
            template: want("template")?.as_str().ok_or("template is not a string")?.to_string(),
            hardening: want("hardening")?.as_bool().ok_or("hardening is not a bool")?,
            region: Region {
                shard: usize_field("shard")?,
                of: usize_field("of")?,
                start: u128_field("start")?,
                end: u128_field("end")?,
            },
            space_size: u128_field("space_size")?,
            with_fi: want("with_fi")?.as_bool().ok_or("with_fi is not a bool")?,
            evals_used: usize_field("evals_used")?,
            cache_hits: usize_field("cache_hits")?,
            points,
            poisoned,
            ledger: LedgerSnapshot::from_json(want("ledger")?).ok_or("malformed ledger")?,
        })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        atomic_write(path, &format!("{}\n", self.to_json()))
    }

    pub fn load(path: &Path) -> Result<ShardArchive, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))?;
        Self::from_json(&j).map_err(|e| format!("{}: {e}", path.display()))
    }
}

/// The merged result: single-process-equivalent frontier and accounting.
#[derive(Debug)]
pub struct Merged {
    pub net: String,
    pub with_fi: bool,
    pub shards: usize,
    pub space_size: u128,
    /// All shard points in canonical order (= single-process enumeration
    /// order when the partition covers the space).
    pub points: Vec<DesignPoint>,
    /// Indices into `points` forming the 2-D Pareto frontier.
    pub frontier_idx: Vec<usize>,
    pub hv2d: f64,
    pub hv3d: f64,
    /// Summed across shards — each unique genotype charged once per shard
    /// that owned it, i.e. exactly once under a disjoint partition.
    pub evals_used: usize,
    pub cache_hits: usize,
    pub poisoned: Vec<(String, String)>,
    pub ledger: LedgerSnapshot,
}

impl Merged {
    pub fn frontier(&self) -> Vec<&DesignPoint> {
        self.frontier_idx.iter().map(|&i| &self.points[i]).collect()
    }
}

/// Fold shard archives into one frontier. Archives may arrive in any
/// order; they are sorted by shard index and validated to be exactly the
/// `of`-way cut of one space before any folding happens.
pub fn merge_archives(mut archives: Vec<ShardArchive>) -> Result<Merged, String> {
    let first = archives.first().ok_or("merge: no shard archives given")?;
    let (net, of, size, with_fi) =
        (first.net.clone(), first.region.of, first.space_size, first.with_fi);
    if archives.len() != of {
        return Err(format!("merge: space was cut {of} ways but {} archives given", archives.len()));
    }
    for a in &archives {
        if a.net != net
            || a.alphabet != first.alphabet
            || a.n_layers != first.n_layers
            || a.template != first.template
            || a.hardening != first.hardening
        {
            return Err(format!("merge: shard {} describes a different search space", a.region.shard));
        }
        if a.region.of != of || a.space_size != size || a.with_fi != with_fi {
            return Err(format!("merge: shard {} disagrees on the cut", a.region.shard));
        }
    }
    archives.sort_by_key(|a| a.region.shard);
    let mut cursor: u128 = 0;
    for (k, a) in archives.iter().enumerate() {
        if a.region.shard != k {
            return Err(format!("merge: shard {k} missing or duplicated"));
        }
        if a.region.start != cursor || a.region.end < a.region.start {
            return Err(format!(
                "merge: shard {k} region {} does not chain at index {cursor}",
                a.region.label()
            ));
        }
        cursor = a.region.end;
    }
    if cursor != size {
        return Err(format!("merge: regions cover only {cursor} of {size} genotypes"));
    }

    let mut points = Vec::with_capacity(archives.iter().map(|a| a.points.len()).sum());
    let mut poisoned = Vec::new();
    let mut evals_used = 0usize;
    let mut cache_hits = 0usize;
    let mut ledger = LedgerSnapshot::default();
    for a in &archives {
        points.extend(a.points.iter().cloned());
        poisoned.extend(a.poisoned.iter().cloned());
        evals_used += a.evals_used;
        cache_hits += a.cache_hits;
        ledger.merge(&a.ledger);
    }

    let (frontier_idx, hv2d) = frontier_hv(&points, with_fi);
    let hv3d = hypervolume3(&points);

    // cross-check the concatenated front against the frontier-of-frontiers
    // computed straight from the per-shard slices — a disagreement means
    // archive corruption (reordered or missing points), not a math bug
    let sets: Vec<&[DesignPoint]> = archives.iter().map(|a| a.points.as_slice()).collect();
    let fy = |p: &DesignPoint| if with_fi { p.fault_vuln_pct } else { p.acc_drop_pct };
    let via_sets = pareto_merge(&sets, |p| p.util_pct, fy);
    let offsets: Vec<usize> = archives
        .iter()
        .scan(0usize, |acc, a| {
            let base = *acc;
            *acc += a.points.len();
            Some(base)
        })
        .collect();
    let via_sets_flat: Vec<usize> = via_sets.iter().map(|&(s, i)| offsets[s] + i).collect();
    if via_sets_flat != frontier_idx {
        return Err("merge: per-shard frontier disagrees with merged frontier — corrupt archive?"
            .to_string());
    }

    Ok(Merged {
        net,
        with_fi,
        shards: of,
        space_size: size,
        points,
        frontier_idx,
        hv2d,
        hv3d,
        evals_used,
        cache_hits,
        poisoned,
        ledger,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(cfg: &str, util: f64, vuln: f64) -> DesignPoint {
        DesignPoint {
            net: "t".into(),
            mult: "mixed".into(),
            mask: 0,
            config_string: cfg.to_string(),
            base_acc: 90.0,
            ax_acc: 88.0,
            acc_drop_pct: vuln / 2.0,
            fi_mean_acc: 80.0,
            fault_vuln_pct: vuln,
            fi_faults: 10,
            fi_ci95_pp: 0.5,
            cycles: 100,
            luts: 200,
            ffs: 50,
            util_pct: util,
            power_mw: 1.0,
        }
    }

    fn archive(shard: usize, of: usize, start: u128, end: u128, pts: Vec<DesignPoint>) -> ShardArchive {
        ShardArchive {
            net: "t".into(),
            alphabet: vec!["exact".into(), "ax1".into()],
            n_layers: 2,
            template: "xx".into(),
            hardening: false,
            region: Region { shard, of, start, end },
            space_size: 4,
            with_fi: true,
            evals_used: pts.len(),
            cache_hits: 0,
            points: pts,
            poisoned: Vec::new(),
            ledger: LedgerSnapshot::default(),
        }
    }

    #[test]
    fn archive_json_roundtrip() {
        let a = archive(1, 2, 2, 4, vec![point("10", 40.0, 3.0), point("11", 55.0, 1.0)]);
        let back = ShardArchive::from_json(&a.to_json()).expect("roundtrip");
        assert_eq!(back.net, a.net);
        assert_eq!(back.region, a.region);
        assert_eq!(back.points.len(), 2);
        assert_eq!(back.points[0].config_string, "10");
        assert_eq!(back.points[0].util_pct.to_bits(), a.points[0].util_pct.to_bits());
        assert_eq!(back.ledger, a.ledger);
    }

    #[test]
    fn merge_rejects_gaps_duplicates_and_mixed_spaces() {
        let a0 = archive(0, 2, 0, 2, vec![point("00", 10.0, 5.0)]);
        let a1 = archive(1, 2, 2, 4, vec![point("10", 40.0, 3.0)]);
        assert!(merge_archives(vec![a0.clone(), a1.clone()]).is_ok());
        // duplicate shard
        assert!(merge_archives(vec![a0.clone(), a0.clone()]).is_err());
        // missing archive entirely
        assert!(merge_archives(vec![a0.clone()]).is_err());
        // gap: shard 1 starts late
        let mut late = a1.clone();
        late.region.start = 3;
        assert!(merge_archives(vec![a0.clone(), late]).is_err());
        // different net
        let mut other = a1.clone();
        other.net = "u".into();
        assert!(merge_archives(vec![a0, other]).is_err());
    }

    #[test]
    fn merge_concatenates_and_sums() {
        let a0 = archive(0, 2, 0, 2, vec![point("00", 10.0, 5.0), point("01", 30.0, 4.0)]);
        let mut a1 = archive(1, 2, 2, 4, vec![point("10", 40.0, 3.0), point("11", 55.0, 1.0)]);
        a1.cache_hits = 1;
        let m = merge_archives(vec![a1, a0]).expect("merge"); // any order in
        assert_eq!(m.points.len(), 4);
        assert_eq!(m.points[0].config_string, "00"); // canonical order out
        assert_eq!(m.evals_used, 4);
        assert_eq!(m.cache_hits, 1);
        // all four points strictly trade off util vs vuln: all on the front
        assert_eq!(m.frontier_idx, vec![0, 1, 2, 3]);
        assert!(m.hv2d > 0.0);
    }
}
