//! `repro serve` — the DSE job-queue daemon.
//!
//! Turns the one-shot CLI into a long-running service: clients submit
//! search-campaign jobs over the Unix-socket protocol
//! ([`super::protocol`]), a fixed pool of runner threads executes up to
//! `max_jobs` campaigns concurrently, and every campaign runs the
//! ordinary journaled search — same fingerprint, same run-id, same
//! journal file as `repro zoo search` would produce — so a served
//! campaign is resumable (and `snapshot`-able) exactly like a CLI one.
//!
//! Concurrency model: each runner thread drives one campaign's
//! planner/executor runtime; evaluation workers for *all* live campaigns
//! lease from the shared [`WorkerBudget`], so N concurrent campaigns
//! multiplex the host instead of oversubscribing it (`status` reports the
//! budget's live/available counts for exactly this reason).
//!
//! Cancellation: a queued job cancels immediately. A running job cancels
//! at its next checkpoint boundary — the [`ServedJournal`] wrapper forces
//! a checkpoint commit and then unwinds the planner with a
//! [`CancelSignal`], so the journal on disk always ends at a committed
//! boundary and the cancelled campaign can later be resubmitted with
//! `resume` to finish from precisely where it stopped.

use std::collections::{BTreeMap, VecDeque};
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::dse::cache::{CacheMark, ResultCache};
use crate::dse::{DesignPoint, Evaluator};
use crate::eval::{Fidelity, FidelitySpec, StagedBackend, StagedEvaluator};
use crate::faultsim::{CampaignParams, FaultModelKind};
use crate::recovery::{
    inspect_run, JournalWriter, Replayed, RunCounters, RunJournal, StateProvider,
};
use crate::search::{
    hypervolume3, run_fingerprint, run_search_journaled, ResultCacheHook, SearchSpace, SearchSpec,
    Strategy,
};
use crate::util::cli::env_usize;
use crate::util::json::{self, Json};
use crate::util::threadpool::WorkerBudget;

use super::protocol::{self, Request};

/// Daemon configuration. The CLI builds this from flags and env
/// ([`ServeConfig::from_env`]); tests construct it directly with a
/// per-test work dir.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Unix socket the daemon listens on.
    pub socket: PathBuf,
    /// Directory for per-job cache files and the `runs/` journal dir.
    pub work_dir: PathBuf,
    /// Campaigns running concurrently (queued beyond that).
    pub max_jobs: usize,
}

impl ServeConfig {
    /// Flags-free construction: socket from `DEEPAXE_SERVE_SOCKET` (else
    /// `results/serve.sock`), concurrency from `DEEPAXE_SERVE_MAX_JOBS`
    /// (else 2), work dir `results`.
    pub fn from_env() -> ServeConfig {
        let socket = std::env::var(protocol::SOCKET_ENV)
            .unwrap_or_else(|_| protocol::DEFAULT_SOCKET.to_string());
        ServeConfig {
            socket: PathBuf::from(socket),
            work_dir: PathBuf::from("results"),
            max_jobs: env_usize(protocol::MAX_JOBS_ENV, protocol::DEFAULT_MAX_JOBS).max(1),
        }
    }
}

/// One search campaign as submitted over the wire. Mirrors the `repro
/// zoo search` knobs — a served job and the equivalent CLI run produce
/// the same fingerprint, hence the same run-id and journal.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Zoo preset or topology spec (`"net"` / `"spec"` in the JSON).
    pub target: String,
    pub seed: u64,
    pub strategy: String,
    pub budget: usize,
    /// Generation/chunk size override; `None` = the strategy default.
    pub pop: Option<usize>,
    pub with_fi: bool,
    pub workers: usize,
    pub sync: bool,
    pub warm_start: bool,
    /// Multiplier names/aliases; empty = the paper's three AxMs.
    pub mults: Vec<String>,
    pub harden: bool,
    pub fault_model: String,
    pub faults: usize,
    pub images: usize,
    pub eval_images: usize,
    /// `None` = the `DEEPAXE_FI_EPSILON` env default, like the CLI.
    pub epsilon_pp: Option<f64>,
    /// `None` = screening off, `Some(0)` = adaptive, `Some(n)` = n faults.
    pub screen: Option<usize>,
    /// Trace-cache byte budget override (MB); `None` = env default.
    /// Scheduling/memory only — deliberately absent from the fingerprint.
    pub trace_cache_mb: Option<usize>,
    /// Journal commit interval; served campaigns always journal (>= 1)
    /// so `snapshot` and checkpoint-boundary cancel have something to
    /// ride on.
    pub checkpoint_every: usize,
    /// Resume a previous (crashed or cancelled) run by run-id.
    pub resume: Option<String>,
    /// Test hook: freeze the persisted journal after k checkpoints while
    /// the run completes — the deterministic kill(-9) stand-in.
    pub limit_checkpoints: Option<usize>,
}

impl JobSpec {
    pub fn from_json(j: &Json) -> Result<JobSpec, String> {
        let target = j
            .get("net")
            .or_else(|| j.get("spec"))
            .and_then(Json::as_str)
            .ok_or("job needs \"net\" (zoo preset) or \"spec\" (topology)")?
            .to_string();
        let usize_or = |k: &str, d: usize| j.get(k).and_then(Json::as_usize).unwrap_or(d);
        let bool_or = |k: &str, d: bool| j.get(k).and_then(Json::as_bool).unwrap_or(d);
        let spec = JobSpec {
            target,
            seed: j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(0x5EED),
            strategy: j
                .get("strategy")
                .and_then(Json::as_str)
                .unwrap_or("nsga2")
                .to_string(),
            budget: usize_or("budget", 64),
            pop: j.get("pop").and_then(Json::as_usize),
            with_fi: bool_or("with_fi", true),
            workers: usize_or("workers", 1),
            sync: bool_or("sync", false),
            warm_start: bool_or("warm_start", false),
            mults: j
                .get("mults")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect())
                .unwrap_or_default(),
            harden: bool_or("harden", false),
            fault_model: j
                .get("fault_model")
                .and_then(Json::as_str)
                .unwrap_or("bitflip")
                .to_string(),
            faults: usize_or("faults", env_usize("DEEPAXE_FI_FAULTS", 60)),
            images: usize_or("images", env_usize("DEEPAXE_FI_IMAGES", 48)),
            eval_images: usize_or("eval_images", env_usize("DEEPAXE_EVAL_IMAGES", 120)),
            epsilon_pp: j.get("fi_epsilon").and_then(Json::as_f64),
            screen: j.get("fi_screen").and_then(Json::as_usize),
            trace_cache_mb: j.get("trace_cache_mb").and_then(Json::as_usize),
            checkpoint_every: usize_or("checkpoint_every", 1),
            resume: j.get("resume").and_then(Json::as_str).map(str::to_string),
            limit_checkpoints: j.get("limit_checkpoints").and_then(Json::as_usize),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Reject malformed jobs at submit time, over the wire — not minutes
    /// later on a runner thread.
    fn validate(&self) -> Result<(), String> {
        Strategy::parse(&self.strategy)?;
        FaultModelKind::parse(&self.fault_model)
            .ok_or_else(|| format!("unknown fault model {:?}", self.fault_model))?;
        if self.checkpoint_every == 0 {
            return Err("served campaigns require journaling: checkpoint_every >= 1".into());
        }
        for m in &self.mults {
            canonical_mult(m)?;
        }
        Ok(())
    }
}

/// Alias-tolerant multiplier lookup against the catalog — the
/// non-panicking counterpart of `report::experiments::mult_name`, since a
/// daemon must answer a bad name over the wire rather than abort.
fn canonical_mult(name: &str) -> Result<String, String> {
    let n = match name {
        "kvp" | "mul8s_1KVP" => "mul8s_1kvp_s",
        "kv9" | "mul8s_1KV9" => "mul8s_1kv9_s",
        "kv8" | "mul8s_1KV8" => "mul8s_1kv8_s",
        other => other,
    };
    if crate::axmul::CATALOG.iter().any(|m| m.name == n) {
        Ok(n.to_string())
    } else {
        Err(format!("unknown multiplier {name:?}"))
    }
}

/// The cancel unwind payload: typed so the runner can tell a cancelled
/// campaign from a genuinely panicking one.
struct CancelSignal;

/// Journal wrapper that turns a cancel flag into a clean stop: at the
/// first live boundary after the flag rises it forces a checkpoint
/// commit, then unwinds the planner with [`CancelSignal`]. Unwinding is
/// safe under the async runtime — `with_executor` installs its shutdown
/// guard before the planner body runs, so workers drain and the scope
/// joins during the unwind.
struct ServedJournal<'a> {
    inner: JournalWriter<'a>,
    cancel: Arc<AtomicBool>,
}

impl RunJournal for ServedJournal<'_> {
    fn replaying(&self) -> bool {
        self.inner.replaying()
    }
    fn replay_eval(&mut self, cfg: &str, fidelity: Fidelity) -> Replayed {
        self.inner.replay_eval(cfg, fidelity)
    }
    fn replay_promotion(&mut self, cfg: &str) -> Replayed {
        self.inner.replay_promotion(cfg)
    }
    fn record_eval(&mut self, cfg: &str, fidelity: Fidelity, hit: bool, point: &DesignPoint) {
        self.inner.record_eval(cfg, fidelity, hit, point);
    }
    fn record_promotion(&mut self, cfg: &str, hit: bool, point: &DesignPoint) {
        self.inner.record_promotion(cfg, hit, point);
    }
    fn record_poison(&mut self, cfg: &str, fidelity: Fidelity, err: &str) {
        self.inner.record_poison(cfg, fidelity, err);
    }
    fn record_warm(&mut self, warm: &[String]) {
        self.inner.record_warm(warm);
    }
    fn warm_override(&self) -> Option<Vec<String>> {
        self.inner.warm_override()
    }
    fn boundary(&mut self, counters: &RunCounters) -> bool {
        let want = self.inner.boundary(counters);
        // never force a commit mid-replay: resume must reach the verified
        // checkpoint state first, then the next live boundary cancels
        if !self.inner.replaying() && self.cancel.load(Ordering::SeqCst) {
            return true;
        }
        want
    }
    fn commit_checkpoint(&mut self, counters: &RunCounters, mark: &CacheMark) {
        self.inner.commit_checkpoint(counters, mark);
        if self.cancel.load(Ordering::SeqCst) {
            std::panic::panic_any(CancelSignal);
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobPhase {
    Queued,
    Running,
    Done,
    Cancelled,
    Failed,
}

impl JobPhase {
    fn name(&self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Running => "running",
            JobPhase::Done => "done",
            JobPhase::Cancelled => "cancelled",
            JobPhase::Failed => "failed",
        }
    }
}

struct JobEntry {
    id: u64,
    spec: JobSpec,
    phase: JobPhase,
    run_id: Option<String>,
    report: Option<Json>,
    error: Option<String>,
    cancel: Arc<AtomicBool>,
}

struct DaemonState {
    jobs: Vec<JobEntry>,
    queue: VecDeque<u64>,
}

struct Shared {
    cfg: ServeConfig,
    state: Mutex<DaemonState>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// A running daemon: accept thread + `max_jobs` runner threads.
pub struct Daemon {
    shared: Arc<Shared>,
    accept: JoinHandle<()>,
    runners: Vec<JoinHandle<()>>,
}

impl Daemon {
    /// Bind the socket and spawn the service threads. A stale socket
    /// file from a dead daemon is removed; a *live* daemon on the same
    /// socket is not detected (last bind wins), so give each daemon its
    /// own work dir.
    pub fn start(cfg: ServeConfig) -> Result<Daemon, String> {
        std::fs::create_dir_all(&cfg.work_dir)
            .map_err(|e| format!("create {}: {e}", cfg.work_dir.display()))?;
        std::fs::create_dir_all(cfg.work_dir.join("runs"))
            .map_err(|e| format!("create runs dir: {e}"))?;
        if let Some(parent) = cfg.socket.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent).map_err(|e| format!("create socket dir: {e}"))?;
        }
        let _ = std::fs::remove_file(&cfg.socket);
        let listener = UnixListener::bind(&cfg.socket)
            .map_err(|e| format!("bind {}: {e}", cfg.socket.display()))?;
        let shared = Arc::new(Shared {
            cfg,
            state: Mutex::new(DaemonState { jobs: Vec::new(), queue: VecDeque::new() }),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let runners = (0..shared.cfg.max_jobs.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || runner_loop(&shared))
            })
            .collect();
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(&shared, listener))
        };
        Ok(Daemon { shared, accept, runners })
    }

    pub fn socket(&self) -> PathBuf {
        self.shared.cfg.socket.clone()
    }

    /// Block until a `shutdown` request arrives, running jobs finish and
    /// every thread exits; then remove the socket file.
    pub fn join(self) {
        let _ = self.accept.join();
        self.shared.cv.notify_all();
        for r in self.runners {
            let _ = r.join();
        }
        let _ = std::fs::remove_file(&self.shared.cfg.socket);
    }
}

fn runner_loop(shared: &Arc<Shared>) {
    loop {
        let id = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(id) = st.queue.pop_front() {
                    break id;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        let (spec, cancel) = {
            let mut st = shared.state.lock().unwrap();
            let e = st.jobs.iter_mut().find(|e| e.id == id).expect("queued job exists");
            if e.phase != JobPhase::Queued {
                continue; // cancelled while still in the queue
            }
            e.phase = JobPhase::Running;
            (e.spec.clone(), Arc::clone(&e.cancel))
        };
        let set_run_id = |rid: String| {
            let mut st = shared.state.lock().unwrap();
            if let Some(e) = st.jobs.iter_mut().find(|e| e.id == id) {
                e.run_id = Some(rid);
            }
        };
        let outcome = run_job(&shared.cfg.work_dir, &spec, &cancel, set_run_id);
        let mut st = shared.state.lock().unwrap();
        let e = st.jobs.iter_mut().find(|e| e.id == id).expect("running job exists");
        match outcome {
            JobOutcome::Done(report) => {
                e.phase = JobPhase::Done;
                e.report = Some(report);
            }
            JobOutcome::Cancelled => e.phase = JobPhase::Cancelled,
            JobOutcome::Failed(msg) => {
                e.phase = JobPhase::Failed;
                e.error = Some(msg);
            }
        }
    }
}

enum JobOutcome {
    Done(Json),
    Cancelled,
    Failed(String),
}

fn panic_message(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "campaign panicked (non-string payload)".to_string()
    }
}

fn run_job(
    work_dir: &Path,
    spec: &JobSpec,
    cancel: &Arc<AtomicBool>,
    set_run_id: impl FnOnce(String),
) -> JobOutcome {
    let result =
        catch_unwind(AssertUnwindSafe(|| run_job_inner(work_dir, spec, cancel, set_run_id)));
    match result {
        Ok(Ok(report)) => JobOutcome::Done(report),
        Ok(Err(msg)) => JobOutcome::Failed(msg),
        Err(p) if p.is::<CancelSignal>() => JobOutcome::Cancelled,
        Err(p) => JobOutcome::Failed(panic_message(p)),
    }
}

/// The `repro zoo search` flow, assembled from a [`JobSpec`] instead of
/// CLI flags — deliberately kept line-for-line parallel to `zoo_search`
/// in `main.rs` so served and CLI campaigns share fingerprints.
fn run_job_inner(
    work_dir: &Path,
    spec: &JobSpec,
    cancel: &Arc<AtomicBool>,
    set_run_id: impl FnOnce(String),
) -> Result<Json, String> {
    let strategy = Strategy::parse(&spec.strategy)?;
    let fault_model = FaultModelKind::parse(&spec.fault_model)
        .ok_or_else(|| format!("unknown fault model {:?}", spec.fault_model))?;
    let fi = CampaignParams {
        n_faults: spec.faults,
        n_images: spec.images,
        seed: spec.seed,
        ..CampaignParams::default_for("zoo")
    };
    let bundle = crate::zoo::build(&spec.target, spec.seed, spec.eval_images.max(fi.n_images))?;
    let net = &bundle.net;
    let luts: BTreeMap<String, crate::axmul::Lut> =
        crate::axmul::CATALOG.iter().map(|m| (m.name.to_string(), m.lut())).collect();
    let mults: Vec<String> = if spec.mults.is_empty() {
        vec!["mul8s_1kvp_s".into(), "mul8s_1kv9_s".into(), "mul8s_1kv8_s".into()]
    } else {
        spec.mults.iter().map(|m| canonical_mult(m)).collect::<Result<_, _>>()?
    };
    let mut space = SearchSpace::paper(net, &mults);
    if spec.harden {
        space = space.with_hardening();
    }
    let ev = Evaluator::new(net, &bundle.data, &luts, spec.eval_images, fi.clone());

    let mut fidelity = FidelitySpec::default_from_env();
    if let Some(e) = spec.epsilon_pp {
        fidelity.epsilon_pp = e;
    }
    if let Some(n) = spec.screen {
        fidelity.screen_faults = n;
        fidelity.screen_auto = n == 0;
    }
    if let Some(mb) = spec.trace_cache_mb {
        fidelity.trace_cache_mb = mb;
    }
    let mut sspec = SearchSpec::new(strategy);
    sspec.budget = spec.budget;
    if let Some(p) = spec.pop {
        sspec.pop = p;
    }
    sspec.seed = spec.seed;
    sspec.with_fi = spec.with_fi;
    sspec.screen = fidelity.screening_enabled();
    sspec.workers = spec.workers;
    sspec.warm_start = spec.warm_start;
    sspec.sync = spec.sync;
    let budget = sspec.resolved_budget(&space);

    let fp = run_fingerprint(
        &net.name,
        &space,
        &sspec,
        budget,
        &fi,
        spec.eval_images,
        fault_model,
        &fidelity,
    );
    let rid = crate::recovery::run_id(&fp);
    set_run_id(rid.clone());

    let runs_dir = work_dir.join("runs");
    let mut cache = ResultCache::open(work_dir.join(format!("serve_cache_{rid}.jsonl")));
    let staged = StagedEvaluator::new_with_model(&ev, fidelity, fault_model);
    let backend = StagedBackend { st: &staged };

    let mut journal = match &spec.resume {
        Some(run) => {
            let j = JournalWriter::resume(&runs_dir, run, &fp, spec.checkpoint_every)?;
            cache.rollback_to(&j.cache_mark()).map_err(|e| format!("cache rollback: {e}"))?;
            if let Some(state) = j.eval_state() {
                staged.restore_state(state);
            }
            j
        }
        None => JournalWriter::create(&runs_dir, &fp, spec.checkpoint_every),
    };
    if let Some(k) = spec.limit_checkpoints {
        journal.limit_checkpoints(k);
    }
    journal.set_provider(&staged);
    cache.set_autoflush(false);
    let mut hook = ResultCacheHook {
        cache: &mut cache,
        net: net.name.clone(),
        fi: fi.clone(),
        eval_images: spec.eval_images,
        fault_model,
    };
    let mut served = ServedJournal { inner: journal, cancel: Arc::clone(cancel) };

    let out = run_search_journaled(&space, &sspec, &backend, &mut hook, &mut served);

    let frontier: Vec<Json> =
        out.frontier().iter().map(|p| json::str(&p.config_string)).collect();
    Ok(json::obj(vec![
        ("run_id", json::str(&rid)),
        ("net", json::str(&net.name)),
        ("strategy", json::str(sspec.strategy.name())),
        ("budget", json::num(budget as f64)),
        ("evals_used", json::num(out.evals_used as f64)),
        ("cache_hits", json::num(out.cache_hits as f64)),
        ("promotions", json::num(out.promotions as f64)),
        ("space_size", json::str(out.space_size.to_string())),
        ("frontier", Json::Arr(frontier)),
        ("hv2d", json::num(out.hypervolume())),
        ("hv3d", json::num(hypervolume3(&out.evaluated))),
        ("poisoned", json::num(out.poisoned.len() as f64)),
        ("ledger", staged.ledger().snapshot().to_json()),
        ("ledger_summary", json::str(staged.ledger().summary(fi.n_faults))),
    ]))
}

fn accept_loop(shared: &Arc<Shared>, listener: UnixListener) {
    for stream in listener.incoming() {
        if let Ok(s) = stream {
            handle_conn(shared, s);
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
}

/// Serve one connection: any number of request lines until EOF (or a
/// shutdown request). Requests are handled in order, one response line
/// each; a malformed line gets an error response instead of a hangup.
fn handle_conn(shared: &Arc<Shared>, stream: UnixStream) {
    let Ok(mut writer) = stream.try_clone() else {
        return;
    };
    let mut reader = std::io::BufReader::new(stream);
    loop {
        let req = match protocol::read_line(&mut reader) {
            Ok(Some(j)) => Request::from_json(&j),
            Ok(None) | Err(_) => return,
        };
        let (resp, stop) = match req {
            Err(e) => (protocol::err(e), false),
            Ok(req) => {
                let stop = matches!(req, Request::Shutdown);
                (dispatch(shared, req), stop)
            }
        };
        if protocol::write_line(&mut writer, &resp).is_err() {
            return;
        }
        if stop {
            return;
        }
    }
}

fn dispatch(shared: &Arc<Shared>, req: Request) -> Json {
    match req {
        Request::Submit { job } => {
            if shared.shutdown.load(Ordering::SeqCst) {
                return protocol::err("daemon is shutting down");
            }
            let spec = match JobSpec::from_json(&job) {
                Ok(s) => s,
                Err(e) => return protocol::err(e),
            };
            let mut st = shared.state.lock().unwrap();
            let id = st.jobs.len() as u64 + 1;
            st.jobs.push(JobEntry {
                id,
                spec,
                phase: JobPhase::Queued,
                run_id: None,
                report: None,
                error: None,
                cancel: Arc::new(AtomicBool::new(false)),
            });
            st.queue.push_back(id);
            drop(st);
            shared.cv.notify_one();
            protocol::ok(vec![("job", json::num(id as f64))])
        }
        Request::Status { job } => {
            let st = shared.state.lock().unwrap();
            let budget = WorkerBudget::global();
            let workers = json::obj(vec![
                ("cap", json::num(budget.cap() as f64)),
                ("live", json::num(budget.live() as f64)),
                ("peak", json::num(budget.peak() as f64)),
                ("available", json::num(budget.available() as f64)),
            ]);
            match job {
                Some(id) => match st.jobs.iter().find(|e| e.id == id) {
                    Some(e) => {
                        protocol::ok(vec![("job", job_json(e, true)), ("workers", workers)])
                    }
                    None => protocol::err(format!("no job {id}")),
                },
                None => {
                    let jobs: Vec<Json> = st.jobs.iter().map(|e| job_json(e, false)).collect();
                    protocol::ok(vec![("jobs", Json::Arr(jobs)), ("workers", workers)])
                }
            }
        }
        Request::Snapshot { job } => {
            let (run_id, phase) = {
                let st = shared.state.lock().unwrap();
                let Some(e) = st.jobs.iter().find(|e| e.id == job) else {
                    return protocol::err(format!("no job {job}"));
                };
                (e.run_id.clone(), e.phase)
            };
            let Some(rid) = run_id else {
                return protocol::err(format!("job {job} has no run-id yet ({})", phase.name()));
            };
            let path = JournalWriter::path_for(&shared.cfg.work_dir.join("runs"), &rid);
            let info = inspect_run(&path);
            protocol::ok(vec![
                ("job", json::num(job as f64)),
                ("state", json::str(phase.name())),
                ("run_id", json::str(&info.run_id)),
                ("journal", json::str(path.display().to_string())),
                ("status", json::str(info.status.name())),
                ("events", json::num(info.events as f64)),
                ("evals_used", json::num(info.evals_used as f64)),
                ("cache_hits", json::num(info.cache_hits as f64)),
                ("promotions", json::num(info.promotions as f64)),
                ("archive_len", json::num(info.archive_len as f64)),
                (
                    "budget",
                    info.budget.map(|b| json::num(b as f64)).unwrap_or(Json::Null),
                ),
            ])
        }
        Request::Cancel { job } => {
            let mut st = shared.state.lock().unwrap();
            let Some(e) = st.jobs.iter_mut().find(|e| e.id == job) else {
                return protocol::err(format!("no job {job}"));
            };
            match e.phase {
                JobPhase::Queued => {
                    e.cancel.store(true, Ordering::SeqCst);
                    e.phase = JobPhase::Cancelled;
                    protocol::ok(vec![("state", json::str("cancelled"))])
                }
                JobPhase::Running => {
                    e.cancel.store(true, Ordering::SeqCst);
                    protocol::ok(vec![("state", json::str("cancelling"))])
                }
                phase => protocol::err(format!("job {job} already {}", phase.name())),
            }
        }
        Request::Shutdown => {
            shared.shutdown.store(true, Ordering::SeqCst);
            shared.cv.notify_all();
            protocol::ok(vec![("state", json::str("shutting down"))])
        }
    }
}

fn job_json(e: &JobEntry, with_report: bool) -> Json {
    let mut pairs = vec![
        ("job", json::num(e.id as f64)),
        ("state", json::str(e.phase.name())),
        ("net", json::str(&e.spec.target)),
        ("strategy", json::str(&e.spec.strategy)),
        ("budget", json::num(e.spec.budget as f64)),
        (
            "run_id",
            e.run_id.as_deref().map(json::str).unwrap_or(Json::Null),
        ),
        (
            "error",
            e.error.as_deref().map(json::str).unwrap_or(Json::Null),
        ),
    ];
    if with_report {
        pairs.push(("report", e.report.clone().unwrap_or(Json::Null)));
    }
    json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_defaults_and_validation() {
        let j = Json::parse(r#"{"net":"zoo-tiny"}"#).unwrap();
        let s = JobSpec::from_json(&j).expect("defaults");
        assert_eq!(s.target, "zoo-tiny");
        assert_eq!(s.strategy, "nsga2");
        assert_eq!(s.budget, 64);
        assert!(s.with_fi);
        assert_eq!(s.checkpoint_every, 1);
        assert!(s.resume.is_none());

        let bad = Json::parse(r#"{"net":"zoo-tiny","strategy":"warp"}"#).unwrap();
        assert!(JobSpec::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"net":"zoo-tiny","checkpoint_every":0}"#).unwrap();
        assert!(JobSpec::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"strategy":"nsga2"}"#).unwrap();
        assert!(JobSpec::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"net":"zoo-tiny","mults":["made_up_mult"]}"#).unwrap();
        assert!(JobSpec::from_json(&bad).is_err());
    }

    #[test]
    fn canonical_mult_aliases() {
        assert_eq!(canonical_mult("kvp").unwrap(), "mul8s_1kvp_s");
        assert_eq!(canonical_mult("mul8s_1kv9_s").unwrap(), "mul8s_1kv9_s");
        assert!(canonical_mult("nope").is_err());
    }
}
