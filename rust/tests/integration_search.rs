//! Search subsystem on the real artifacts: budgeted heuristics vs the
//! exhaustive grid (the acceptance bar: NSGA-II at ≤25% of the exhaustive
//! evaluations reaches ≥95% of its frontier hypervolume), heterogeneous
//! caching, and the pipeline's strategy dispatch.

mod common;

use deepaxe::coordinator::jobs::{run_sweep, SweepSpec};
use deepaxe::coordinator::pipeline::{run_pipeline, PipelineSpec};
use deepaxe::dse::cache::ResultCache;
use deepaxe::dse::{enumerate_masks, pareto_front, Evaluator};
use deepaxe::eval::Fidelity;
use deepaxe::faultsim::{CampaignParams, FaultModelKind, SiteSampling};
use deepaxe::search::{
    frontier_hv, run_search, EvaluatorBackend, NoCache, ResultCacheHook, SearchSpace,
    SearchSpec, Strategy,
};

fn fi_params(n_faults: usize, n_images: usize, seed: u64) -> CampaignParams {
    CampaignParams {
        n_faults,
        n_images,
        seed,
        workers: 1,
        sampling: SiteSampling::UniformLayer,
        replay: true,
        gate: true,
        delta: true,
        batch: true,
    }
}

fn paper_mults() -> Vec<String> {
    deepaxe::axmul::PAPER_AXMS.iter().map(|m| m.to_string()).collect()
}

#[test]
fn nsga2_quarter_budget_reaches_95pct_of_exhaustive_hypervolume() {
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = fi_params(12, 24, 0x5EA7C4);
    let ev = Evaluator::new(&net, &data, &ctx.luts, 64, fi.clone());

    // exhaustive reference: the paper's per-AxM mask grid, fault-simulated
    let dir = std::env::temp_dir().join(format!("deepaxe_search_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cache_path = dir.join("search_results.jsonl");
    let _ = std::fs::remove_file(&cache_path);
    let mut cache = ResultCache::open(&cache_path);
    let ex_spec = SweepSpec {
        mults: deepaxe::axmul::PAPER_AXMS.to_vec(),
        masks: enumerate_masks(net.n_comp()),
        with_fi: true,
    };
    let ex_evals = ex_spec.n_points();
    let ex_points = run_sweep(&ev, &mut cache, &ex_spec).unwrap();
    let (ex_front, ex_hv) = frontier_hv(&ex_points, true);
    assert!(!ex_front.is_empty());
    assert!(ex_hv > 0.0);

    // budgeted NSGA-II over the generalized space, fixed seed; sharing the
    // sweep's cache lets the homogeneous warm-start seeds hit disk (they
    // still consume budget — see driver docs)
    let space = SearchSpace::paper(&net, &paper_mults());
    assert_eq!(space.size(), 4u128.pow(5));
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = ex_evals / 4; // ≤ 25% of the exhaustive evaluations
    spec.seed = fi.seed;
    let backend = EvaluatorBackend { ev: &ev };
    let mut hook = ResultCacheHook {
        cache: &mut cache,
        net: net.name.clone(),
        fi: fi.clone(),
        eval_images: 64,
        fault_model: FaultModelKind::BitFlip,
    };
    let out = run_search(&space, &spec, &backend, &mut hook);
    assert!(out.cache_hits >= 19, "homogeneous seeds should hit the sweep cache");

    assert!(out.evals_used <= ex_evals / 4, "{} > {}", out.evals_used, ex_evals / 4);
    assert!(!out.frontier_idx.is_empty());
    let ratio = out.hypervolume() / ex_hv;
    assert!(
        ratio >= 0.95,
        "nsga2 at {} evals reached only {:.1}% of the exhaustive hypervolume \
         ({:.1} vs {:.1} over {} evals)",
        out.evals_used,
        ratio * 100.0,
        out.hypervolume(),
        ex_hv,
        ex_evals,
    );
}

#[test]
fn full_budget_heuristics_reproduce_exhaustive_frontier() {
    // alphabet [exact, kvp] on mlp3: 2^3 = 8 configs — budget covers the
    // space, so every strategy must return the exact exhaustive frontier
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = fi_params(6, 12, 0xF00);
    let ev = Evaluator::new(&net, &data, &ctx.luts, 48, fi);
    let space = SearchSpace::paper(&net, &["mul8s_1kvp_s".to_string()]);
    assert_eq!(space.size(), 8);
    let backend = EvaluatorBackend { ev: &ev };

    let coords = |o: &deepaxe::search::SearchOutcome| {
        let mut v: Vec<(i64, i64)> = o
            .frontier()
            .iter()
            .map(|p| ((p.util_pct * 1e9) as i64, (p.fault_vuln_pct * 1e9) as i64))
            .collect();
        v.sort();
        v
    };
    let mut ex_spec = SearchSpec::new(Strategy::Exhaustive);
    ex_spec.budget = 8;
    let exhaustive = run_search(&space, &ex_spec, &backend, &mut NoCache);
    assert_eq!(exhaustive.evals_used, 8);
    for strategy in [Strategy::Nsga2, Strategy::Anneal, Strategy::HillClimb] {
        let mut spec = SearchSpec::new(strategy);
        spec.budget = 8;
        let out = run_search(&space, &spec, &backend, &mut NoCache);
        assert_eq!(out.evals_used, 8, "{strategy:?}");
        assert_eq!(coords(&out), coords(&exhaustive), "{strategy:?}");
    }
}

#[test]
fn heterogeneous_results_cache_and_reload() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = fi_params(4, 8, 0xCAC4E);
    let ev = Evaluator::new(&net, &data, &ctx.luts, 32, fi.clone());
    let space = SearchSpace::paper(&net, &paper_mults());

    let dir = std::env::temp_dir().join(format!("deepaxe_search_cache_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("results.jsonl");
    let _ = std::fs::remove_file(&path);

    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 12;
    spec.seed = 42;
    let backend = EvaluatorBackend { ev: &ev };

    // heterogeneous assignments go through the generalized cfg: keys
    {
        use deepaxe::search::CacheHook;
        let mut cache = ResultCache::open(&path);
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: net.name.clone(),
            fi: fi.clone(),
            eval_images: 32,
            fault_model: FaultModelKind::BitFlip,
        };
        let g = vec![1u8, 2, 0]; // kvp on layer 0, kv9 on layer 1, exact
        assert!(space.homogeneous(&g).is_none());
        let names = space.decode(&g);
        assert!(hook.get(&names, Fidelity::FiFull).is_none());
        let p = ev.evaluate_assignment(&names, true);
        assert_eq!(p.mult, "mixed");
        assert_eq!(p.mask, 0b011);
        hook.put(&names, Fidelity::FiFull, &p);
        assert_eq!(hook.get(&names, Fidelity::FiFull).as_ref(), Some(&p));
        // a full-fidelity entry also serves screen-tier lookups for free
        assert_eq!(hook.get(&names, Fidelity::FiScreen).as_ref(), Some(&p));
        // reload from disk: still there
        drop(hook);
        let mut cache2 = ResultCache::open(&path);
        let hook2 = ResultCacheHook {
            cache: &mut cache2,
            net: net.name.clone(),
            fi: fi.clone(),
            eval_images: 32,
            fault_model: FaultModelKind::BitFlip,
        };
        assert_eq!(hook2.get(&names, Fidelity::FiFull).as_ref(), Some(&p));
    }
    let _ = std::fs::remove_file(&path);

    let first = {
        let mut cache = ResultCache::open(&path);
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: net.name.clone(),
            fi: fi.clone(),
            eval_images: 32,
            fault_model: FaultModelKind::BitFlip,
        };
        run_search(&space, &spec, &backend, &mut hook)
    };
    assert_eq!(first.cache_hits, 0);

    // same seed, warm cache: every evaluation must be served from disk
    let second = {
        let mut cache = ResultCache::open(&path);
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: net.name.clone(),
            fi: fi.clone(),
            eval_images: 32,
            fault_model: FaultModelKind::BitFlip,
        };
        run_search(&space, &spec, &backend, &mut hook)
    };
    assert_eq!(second.evals_used, first.evals_used);
    assert_eq!(second.cache_hits, second.evals_used);
    assert_eq!(second.genotypes, first.genotypes);
}

#[test]
fn staged_backend_with_epsilon_zero_is_bit_identical_to_monolithic_backend() {
    // acceptance criterion: with early stopping disabled (--fi-epsilon 0,
    // screen=full) the staged ladder reproduces the pre-ladder search
    // output exactly — same genotype trajectory, same design points
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = fi_params(6, 12, 0xB17);
    let ev = Evaluator::new(&net, &data, &ctx.luts, 48, fi);
    let space = SearchSpace::paper(&net, &paper_mults());
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 12;
    spec.seed = 0xB17;

    let mono = run_search(&space, &spec, &EvaluatorBackend { ev: &ev }, &mut NoCache);
    let staged_ev = StagedEvaluator::new(&ev, FidelitySpec::exact());
    let staged =
        run_search(&space, &spec, &StagedBackend { st: &staged_ev }, &mut NoCache);
    assert_eq!(mono.genotypes, staged.genotypes, "search trajectory must not change");
    assert_eq!(mono.evaluated.len(), staged.evaluated.len());
    for (a, b) in mono.evaluated.iter().zip(&staged.evaluated) {
        assert_eq!(a, b, "design points must be bit-identical");
    }
    assert_eq!(staged_ev.ledger().early_stops(), 0);
}

#[test]
fn pipeline_dispatches_heuristic_strategy() {
    let ctx = common::ctx();
    let spec = PipelineSpec {
        net: "mlp3".into(),
        mults: vec!["mul8s_1kvp_s".into(), "mul8s_1kv8_s".into()],
        max_acc_drop_pct: 50.0,
        max_vuln_pct: 100.0,
        eval_images: 48,
        fi: fi_params(6, 12, 0xBEE),
        strategy: Strategy::Nsga2,
        budget: 10,
        fi_epsilon: 0.0,
        fi_screen: 0,
        fi_screen_auto: false,
    };
    let out = run_pipeline(&ctx, &spec).unwrap();
    assert!(out.evals_used <= 10);
    assert!(!out.fi_points.is_empty());
    assert!(!out.frontier.is_empty());
    assert!(out.hypervolume > 0.0);
    let sel = out.selected.expect("loose constraints must select a design");
    for p in &out.feasible {
        assert!(sel.util_pct <= p.util_pct + 1e-12);
    }
}

#[test]
fn screened_search_shares_trace_prefixes_across_genotypes() {
    // acceptance criterion: a multi-genotype screened search run on real
    // artifacts reports nonzero prefix_hits (clean traces inherited
    // across genotypes sharing a layer prefix) and delta-patched replays,
    // and the outcome matches a run with the trace cache disabled
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = fi_params(12, 12, 0x9F1);
    let ev = Evaluator::new(&net, &data, &ctx.luts, 32, fi.clone());
    let space = SearchSpace::paper(&net, &paper_mults());
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 16;
    spec.seed = 0x9F1;
    spec.screen = true;
    let mk_spec = || FidelitySpec { screen_faults: 4, ..FidelitySpec::exact() };

    let staged = StagedEvaluator::new(&ev, mk_spec());
    let out = run_search(&space, &spec, &StagedBackend { st: &staged }, &mut NoCache);
    let ledger = staged.ledger();
    assert!(ledger.prefix_hits() > 0, "{}", ledger.summary(fi.n_faults));
    assert!(ledger.prefix_layers_reused() > 0);
    assert!(ledger.delta_replays() > 0);
    let s = ledger.summary(fi.n_faults);
    assert!(s.contains("prefix_hits") && s.contains("delta-patched"), "{s}");

    // trace-cache state never changes results: cold cache, same outcome
    let cold = StagedEvaluator::new(&ev, FidelitySpec { trace_cache_mb: 0, ..mk_spec() });
    let out2 = run_search(&space, &spec, &StagedBackend { st: &cold }, &mut NoCache);
    assert_eq!(out.genotypes, out2.genotypes);
    for (a, b) in out.evaluated.iter().zip(&out2.evaluated) {
        assert_eq!(a, b, "prefix sharing must be bit-identical");
    }
    assert_eq!(cold.ledger().prefix_hits(), 0);
}

// ===========================================================================
// zoo_ — artifact-free search on generated networks (these are the tests
// scripts/ci.sh runs unconditionally: no common::ctx(), no manifest)
// ===========================================================================

fn zoo_luts() -> std::collections::BTreeMap<String, deepaxe::axmul::Lut> {
    deepaxe::axmul::CATALOG.iter().map(|m| (m.name.to_string(), m.lut())).collect()
}

#[test]
fn zoo_deep_net_search_runs_where_exhaustive_cannot() {
    // the acceptance criterion: budgeted NSGA-II + anneal on a
    // 16-computing-layer generated net whose 4^16 space no exhaustive
    // sweep can enumerate, staged fidelity end to end, both hypervolume
    // indicators finite — with zero artifacts
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let bundle = deepaxe::zoo::build("mlp-deep-16", 0x5EED, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(8, 10, 0x5EED);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 32, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    assert_eq!(space.n_layers, 16);
    assert!(space.size() > 4_000_000_000u128, "space must be beyond enumeration");

    for strategy in [Strategy::Nsga2, Strategy::Anneal] {
        let staged = StagedEvaluator::new(
            &ev,
            FidelitySpec { screen_faults: 3, epsilon_pp: 0.5, ..FidelitySpec::exact() },
        );
        let mut spec = SearchSpec::new(strategy);
        spec.budget = 20;
        spec.seed = fi.seed;
        spec.screen = true;
        let out = run_search(&space, &spec, &StagedBackend { st: &staged }, &mut NoCache);
        assert_eq!(out.evals_used, 20, "{strategy:?} must spend the whole budget");
        assert!(!out.frontier_idx.is_empty(), "{strategy:?}");
        assert!(out.hypervolume() > 0.0, "{strategy:?}");
        assert!(deepaxe::search::hypervolume3(&out.evaluated).is_finite(), "{strategy:?}");
        // frontier survivors were promoted to full fidelity
        for &i in &out.frontier_idx {
            assert_eq!(
                out.fidelities[i],
                deepaxe::eval::Fidelity::FiFull,
                "{strategy:?} frontier point {i}"
            );
        }
        assert!(staged.ledger().total_faults() > 0, "{strategy:?} must run FI");
    }
}

#[test]
fn zoo_staged_epsilon_zero_is_bit_identical_to_monolithic() {
    // the delta/prefix parity suite, zoo-backed: with every early-stop
    // disabled the staged ladder reproduces the monolithic evaluator
    // bit-for-bit on a generated conv net
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let bundle = deepaxe::zoo::build("zoo-tiny", 0xB17, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(10, 12, 0xB17);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 32, fi);
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 14;
    spec.seed = 0xB17;

    let mono = run_search(&space, &spec, &EvaluatorBackend { ev: &ev }, &mut NoCache);
    let staged_ev = StagedEvaluator::new(&ev, FidelitySpec::exact());
    let staged = run_search(&space, &spec, &StagedBackend { st: &staged_ev }, &mut NoCache);
    assert_eq!(mono.genotypes, staged.genotypes);
    for (a, b) in mono.evaluated.iter().zip(&staged.evaluated) {
        assert_eq!(a, b, "zoo design points must be bit-identical");
    }
    assert_eq!(staged_ev.ledger().early_stops(), 0);
}

#[test]
fn zoo_screened_search_shares_trace_prefixes() {
    // zoo-backed prefix parity: a screened multi-genotype run on a
    // generated net reports prefix reuse and delta replays, and disabling
    // the trace cache changes nothing but the rework counters
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let bundle = deepaxe::zoo::build("zoo-tiny", 0x9F1, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(12, 10, 0x9F1);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 16;
    spec.seed = 0x9F1;
    spec.screen = true;
    let mk_spec = || FidelitySpec { screen_faults: 4, ..FidelitySpec::exact() };

    let staged = StagedEvaluator::new(&ev, mk_spec());
    let out = run_search(&space, &spec, &StagedBackend { st: &staged }, &mut NoCache);
    let ledger = staged.ledger();
    assert!(ledger.prefix_hits() > 0, "{}", ledger.summary(fi.n_faults));
    assert!(ledger.delta_replays() > 0);

    let cold = StagedEvaluator::new(&ev, FidelitySpec { trace_cache_mb: 0, ..mk_spec() });
    let out2 = run_search(&space, &spec, &StagedBackend { st: &cold }, &mut NoCache);
    assert_eq!(out.genotypes, out2.genotypes);
    for (a, b) in out.evaluated.iter().zip(&out2.evaluated) {
        assert_eq!(a, b, "zoo prefix sharing must be bit-identical");
    }
    assert_eq!(cold.ledger().prefix_hits(), 0);
}

#[test]
fn zoo_warm_start_seeds_search_from_cached_frontier() {
    // satellite: SearchSpec::warm_start seeds the initial population from
    // ResultCache frontier entries for the same (net, alphabet), budget
    // accounting unchanged
    use deepaxe::search::CacheHook;
    let bundle = deepaxe::zoo::build("zoo-tiny", 0x44, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(6, 8, 0x44);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    // a 3-symbol alphabet: 27 configs, 9 structured seeds — budgets below
    // keep the heuristic branch (no exhaustive degeneration)
    let mults: Vec<String> = vec!["mul8s_1kvp_s".into(), "mul8s_1kv9_s".into()];
    let space = SearchSpace::paper(&bundle.net, &mults);
    assert_eq!(space.size(), 27);
    let n_seeds = space.seeds().len();

    let dir = std::env::temp_dir().join(format!("deepaxe_zoo_warm_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("zoo_results.jsonl");
    let _ = std::fs::remove_file(&path);
    let backend = EvaluatorBackend { ev: &ev };
    let budget = 14;
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = budget;
    spec.seed = 0x44;

    // run 1: populate the cache
    let first = {
        let mut cache = ResultCache::open(&path);
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: bundle.net.name.clone(),
            fi: fi.clone(),
            eval_images: 24,
            fault_model: FaultModelKind::BitFlip,
        };
        run_search(&space, &spec, &backend, &mut hook)
    };

    // the recorded warm pool is exactly run 1's archive frontier
    let mut cache = ResultCache::open(&path);
    let mut hook = ResultCacheHook {
        cache: &mut cache,
        net: bundle.net.name.clone(),
        fi: fi.clone(),
        eval_images: 24,
        fault_model: FaultModelKind::BitFlip,
    };
    let warm = hook.warm_genotypes(&space);
    assert!(!warm.is_empty());
    // every warm genotype is one run 1 evaluated, and its point is
    // non-dominated within run 1's archive (coordinate ties between
    // distinct genotypes make exact genotype-set equality ill-defined,
    // so assert frontier membership by coordinates)
    let coord = |p: &deepaxe::dse::DesignPoint| {
        ((p.util_pct * 1e9) as i64, (p.fault_vuln_pct * 1e9) as i64)
    };
    let front_coords: Vec<_> =
        first.frontier_idx.iter().map(|&i| coord(&first.evaluated[i])).collect();
    for g in &warm {
        let pos = first
            .genotypes
            .iter()
            .position(|h| h == g)
            .unwrap_or_else(|| panic!("warm seed {g:?} was never evaluated by run 1"));
        assert!(
            front_coords.contains(&coord(&first.evaluated[pos])),
            "warm seed {g:?} is not on run 1's frontier"
        );
    }

    // run 2, warm-started: the first (budget - n_seeds) warm genotypes are
    // guaranteed into the initial population; budget semantics unchanged
    spec.warm_start = true;
    spec.seed = 0x45; // different trajectory, same warm pool
    let second = run_search(&space, &spec, &backend, &mut hook);
    assert!(second.evals_used <= budget);
    let guaranteed = warm.len().min(budget.saturating_sub(n_seeds));
    for g in warm.iter().filter(|g| !space.seeds().contains(g)).take(guaranteed) {
        assert!(second.genotypes.contains(g), "warm seed {g:?} missing from archive");
    }
    assert!(second.cache_hits > 0, "warm seeds should be served from the cache");
}

// ===========================================================================
// recovery_ — crash-safe journaled search, artifact-free (scripts/ci.sh
// runs these unconditionally alongside the zoo_ stage)
// ===========================================================================

/// One kill-and-resume scenario. Three runs over the same zoo net and
/// seed: a plain (unjournaled) reference, a journaled run whose journal
/// is frozen at checkpoint 2 — the atomic temp-file+rename commit
/// discipline means a kill -9 leaves exactly such a file — and a
/// `--resume`-style replay of the frozen journal on a fresh evaluator
/// with the result cache rolled back to the checkpointed byte length.
/// All three must agree bit-for-bit: trajectory, design points,
/// counters, both hypervolume indicators, and the FI ledger.
fn resume_case(screen: bool, tag: &str) {
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    use deepaxe::recovery::{JournalWriter, RunJournal, StateProvider};
    use deepaxe::search::run_search_journaled;

    let bundle = deepaxe::zoo::build("zoo-tiny", 0x7E5, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(10, 10, 0x7E5);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 16;
    spec.pop = 4; // several generations => several checkpoint boundaries
    spec.seed = 0x7E5;
    spec.screen = screen;
    let mk_spec = || {
        if screen {
            FidelitySpec { screen_faults: 4, epsilon_pp: 0.5, ..FidelitySpec::exact() }
        } else {
            FidelitySpec::exact()
        }
    };
    let dir =
        std::env::temp_dir().join(format!("deepaxe_recovery_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let runs = dir.join("runs");
    let fp = format!("it-resume screen={screen}");

    // 1. unjournaled reference on its own fresh cache
    let ref_staged = StagedEvaluator::new(&ev, mk_spec());
    let reference = {
        let mut cache = ResultCache::open(&dir.join("ref.jsonl"));
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: bundle.net.name.clone(),
            fi: fi.clone(),
            eval_images: 24,
            fault_model: FaultModelKind::BitFlip,
        };
        run_search(&space, &spec, &StagedBackend { st: &ref_staged }, &mut hook)
    };
    assert!(reference.poisoned.is_empty());

    // 2. journaled run, journal frozen at checkpoint 2 (simulated crash)
    let crash_path = dir.join("crash.jsonl");
    let run = {
        let full_staged = StagedEvaluator::new(&ev, mk_spec());
        let mut cache = ResultCache::open(&crash_path);
        cache.set_autoflush(false);
        let mut journal = JournalWriter::create(&runs, &fp, 1);
        let run = journal.run_id().to_string();
        journal.limit_checkpoints(2);
        journal.set_provider(&full_staged);
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: bundle.net.name.clone(),
            fi: fi.clone(),
            eval_images: 24,
            fault_model: FaultModelKind::BitFlip,
        };
        let full = run_search_journaled(
            &space,
            &spec,
            &StagedBackend { st: &full_staged },
            &mut hook,
            &mut journal,
        );
        // journaling itself must not perturb the search (checkpoint-every
        // 0, i.e. the unjournaled flow, stays bit-for-bit reproducible)
        assert_eq!(full.genotypes, reference.genotypes, "journaled != plain");
        for (a, b) in full.evaluated.iter().zip(&reference.evaluated) {
            assert_eq!(a, b, "journaled design points must match the plain run");
        }
        run
    };

    // 3. resume the frozen journal: fresh evaluator, cache rolled back
    let staged = StagedEvaluator::new(&ev, mk_spec());
    let mut cache = ResultCache::open(&crash_path);
    cache.set_autoflush(false);
    let mut journal = JournalWriter::resume(&runs, &run, &fp, 1).unwrap();
    assert!(journal.replaying(), "resume must start in replay mode");
    cache.rollback_to(&journal.cache_mark()).unwrap();
    if let Some(state) = journal.eval_state() {
        staged.restore_state(state);
    }
    journal.set_provider(&staged);
    let resumed = {
        let mut hook = ResultCacheHook {
            cache: &mut cache,
            net: bundle.net.name.clone(),
            fi: fi.clone(),
            eval_images: 24,
            fault_model: FaultModelKind::BitFlip,
        };
        run_search_journaled(&space, &spec, &StagedBackend { st: &staged }, &mut hook, &mut journal)
    };

    assert_eq!(resumed.genotypes, reference.genotypes, "resumed trajectory diverged");
    assert_eq!(resumed.fidelities, reference.fidelities);
    assert_eq!(resumed.evals_used, reference.evals_used, "budget count must restore");
    assert_eq!(resumed.cache_hits, reference.cache_hits);
    assert_eq!(resumed.promotions, reference.promotions);
    assert_eq!(resumed.frontier_idx, reference.frontier_idx);
    for (a, b) in resumed.evaluated.iter().zip(&reference.evaluated) {
        assert_eq!(a, b, "resumed design points must be bit-identical");
    }
    assert_eq!(resumed.hypervolume().to_bits(), reference.hypervolume().to_bits());
    assert_eq!(
        deepaxe::search::hypervolume3(&resumed.evaluated).to_bits(),
        deepaxe::search::hypervolume3(&reference.evaluated).to_bits(),
    );
    assert_eq!(
        staged.ledger().snapshot(),
        ref_staged.ledger().snapshot(),
        "FI ledger must restore bit-identically"
    );
    assert_eq!(
        staged.ledger().summary(fi.n_faults),
        ref_staged.ledger().summary(fi.n_faults),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recovery_resume_is_bit_identical_full_fidelity() {
    resume_case(false, "full");
}

#[test]
fn recovery_resume_is_bit_identical_with_fi_screen() {
    resume_case(true, "screen");
}

/// A backend that panics on one specific assignment — stand-in for a
/// buggy accelerator kernel taking down a worker.
struct PanickingBackend<'a> {
    inner: EvaluatorBackend<'a>,
    poison: Vec<String>,
}

impl deepaxe::search::EvalBackend for PanickingBackend<'_> {
    fn eval(&self, names: &[&str], fidelity: Fidelity) -> deepaxe::dse::DesignPoint {
        if names.len() == self.poison.len()
            && names.iter().zip(&self.poison).all(|(a, b)| *a == b.as_str())
        {
            panic!("injected evaluator fault");
        }
        self.inner.eval(names, fidelity)
    }
}

#[test]
fn recovery_panicking_genotype_is_quarantined_and_replayable() {
    // a genotype that panics twice is quarantined as a poisoned design
    // point: no budget charge, never re-proposed, the search completes,
    // and the journal both records the poison and replays it on resume
    use deepaxe::recovery::JournalWriter;
    use deepaxe::search::run_search_journaled;

    let bundle = deepaxe::zoo::build("zoo-tiny", 0xDEAD, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(6, 8, 0xDEAD);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    // poison the all-exact structured seed: first into every initial
    // population, so the quarantine path always triggers
    let poison: Vec<String> = vec!["exact".to_string(); space.n_layers];
    let backend = PanickingBackend { inner: EvaluatorBackend { ev: &ev }, poison };
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 12;
    spec.pop = 4;
    spec.seed = 0xDEAD;

    let dir = std::env::temp_dir().join(format!("deepaxe_recovery_poison_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let fp = "it-poison";

    let mut journal = JournalWriter::create(&dir, fp, 1);
    let run = journal.run_id().to_string();
    journal.limit_checkpoints(1); // freeze right after the poisoned batch
    let out = run_search_journaled(&space, &spec, &backend, &mut NoCache, &mut journal);
    assert_eq!(out.poisoned.len(), 1, "exactly the injected genotype must poison");
    let (bad, err) = &out.poisoned[0];
    assert!(space.decode(bad).iter().all(|n| *n == "exact"));
    assert!(err.contains("injected evaluator fault"), "{err}");
    assert!(!out.genotypes.contains(bad), "poisoned genotype must not enter the archive");
    assert!(!out.frontier_idx.is_empty(), "search must complete around the poison");
    assert!(out.evals_used <= spec.budget);
    // the journal records the poison for post-mortem triage
    let text = std::fs::read_to_string(journal.path()).unwrap();
    assert!(text.contains("\"poison\""), "journal must record the poisoned point");

    // resume replays the recorded poison instead of re-running the
    // panicking evaluation, and re-quarantines the genotype
    let mut journal2 = JournalWriter::resume(&dir, &run, fp, 1).unwrap();
    let resumed = run_search_journaled(&space, &spec, &backend, &mut NoCache, &mut journal2);
    assert_eq!(resumed.poisoned, out.poisoned);
    assert_eq!(resumed.genotypes, out.genotypes);
    for (a, b) in resumed.evaluated.iter().zip(&out.evaluated) {
        assert_eq!(a, b, "resume across a poison must stay bit-identical");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ===========================================================================
// async_ — barrier-free planner/executor runtime vs the --sync generational
// path, artifact-free (scripts/ci.sh runs these unconditionally). The
// executor consumes results in submission order (completion clock), so
// every observable output must be bit-identical to the barrier loop.
// ===========================================================================

fn assert_bit_identical(
    a: &deepaxe::search::SearchOutcome,
    b: &deepaxe::search::SearchOutcome,
    tag: &str,
) {
    assert_eq!(a.genotypes, b.genotypes, "{tag}: trajectory");
    assert_eq!(a.fidelities, b.fidelities, "{tag}: fidelities");
    assert_eq!(a.evals_used, b.evals_used, "{tag}: budget account");
    assert_eq!(a.cache_hits, b.cache_hits, "{tag}: cache hits");
    assert_eq!(a.promotions, b.promotions, "{tag}: promotions");
    assert_eq!(a.frontier_idx, b.frontier_idx, "{tag}: frontier");
    assert_eq!(a.poisoned, b.poisoned, "{tag}: poisoned points");
    assert_eq!(a.evaluated.len(), b.evaluated.len(), "{tag}: archive size");
    for (x, y) in a.evaluated.iter().zip(&b.evaluated) {
        assert_eq!(x, y, "{tag}: design points must be bit-identical");
    }
    assert_eq!(a.hypervolume().to_bits(), b.hypervolume().to_bits(), "{tag}: hv2d");
    assert_eq!(
        deepaxe::search::hypervolume3(&a.evaluated).to_bits(),
        deepaxe::search::hypervolume3(&b.evaluated).to_bits(),
        "{tag}: hv3d"
    );
}

#[test]
fn async_staged_zoo_search_matches_sync_any_worker_count() {
    // the tentpole acceptance criterion on the real fidelity ladder: the
    // async runtime at any worker count reproduces the --sync archive,
    // budget, and FI ledger, with and without screening
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let bundle = deepaxe::zoo::build("zoo-tiny", 0xA57C, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(8, 10, 0xA57C);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    for screen in [false, true] {
        let mk_spec = || {
            if screen {
                FidelitySpec { screen_faults: 4, ..FidelitySpec::exact() }
            } else {
                FidelitySpec::exact()
            }
        };
        let run = |sync: bool, workers: usize| {
            let staged = StagedEvaluator::new(&ev, mk_spec());
            let mut spec = SearchSpec::new(Strategy::Nsga2);
            spec.budget = 16;
            spec.seed = 0xA57C;
            spec.screen = screen;
            spec.workers = workers;
            spec.sync = sync;
            let out = run_search(&space, &spec, &StagedBackend { st: &staged }, &mut NoCache);
            (out, staged.ledger().snapshot(), staged.ledger().summary(fi.n_faults))
        };
        let (sync_out, sync_snap, sync_sum) = run(true, 4);
        assert!(sync_out.executor.is_none(), "--sync must not lease an executor");
        for workers in [1usize, 4] {
            let tag = format!("screen={screen} workers={workers}");
            let (out, snap, sum) = run(false, workers);
            assert_bit_identical(&sync_out, &out, &tag);
            assert_eq!(sync_snap, snap, "{tag}: FI ledger snapshot");
            assert_eq!(sync_sum, sum, "{tag}: FI ledger summary");
            let stats = out.executor.expect("async outcome must report executor stats");
            assert!(stats.jobs > 0, "{tag}: evaluations must go through the clock");
        }
    }
}

#[test]
fn async_exhaustive_pipeline_matches_sync_on_zoo_net() {
    // the exhaustive branch pipelines across chunks (all misses submitted
    // up front, checkpoint/promotion of chunk k overlapping chunk k+1) —
    // the archive, promotions, and ledger must not notice
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    let bundle = deepaxe::zoo::build("zoo-tiny", 0xE4A, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(6, 8, 0xE4A);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    assert_eq!(space.size(), 64, "zoo-tiny x 4 symbols: small enough to enumerate");
    let run = |sync: bool| {
        let staged = StagedEvaluator::new(
            &ev,
            FidelitySpec { screen_faults: 3, ..FidelitySpec::exact() },
        );
        let mut spec = SearchSpec::new(Strategy::Exhaustive);
        spec.budget = 64;
        spec.pop = 8; // several chunks => the pipelined plan/consume path
        spec.seed = 0xE4A;
        spec.screen = true;
        spec.workers = 4;
        spec.sync = sync;
        let out = run_search(&space, &spec, &StagedBackend { st: &staged }, &mut NoCache);
        (out, staged.ledger().snapshot())
    };
    let (sync_out, sync_snap) = run(true);
    assert_eq!(sync_out.evals_used, 64, "exhaustive must cover the space");
    let (async_out, async_snap) = run(false);
    assert_bit_identical(&sync_out, &async_out, "exhaustive");
    assert_eq!(sync_snap, async_snap, "exhaustive: FI ledger");
    assert!(async_out.executor.is_some());
}

#[test]
fn async_resume_of_sync_written_journal_is_bit_identical() {
    // run fingerprints exclude worker count and execution mode: a journal
    // recorded under --sync resumes under the async runtime (and vice
    // versa) to the same frontier, budget, and ledger as an uninterrupted
    // sync run
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    use deepaxe::recovery::{JournalWriter, RunJournal, StateProvider};
    use deepaxe::search::run_search_journaled;

    let bundle = deepaxe::zoo::build("zoo-tiny", 0xAE5, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(8, 10, 0xAE5);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi.clone());
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    let mk_spec = || FidelitySpec { screen_faults: 4, ..FidelitySpec::exact() };
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 16;
    spec.pop = 4; // several generations => several checkpoint boundaries
    spec.seed = 0xAE5;
    spec.screen = true;
    spec.sync = true; // the journal is recorded under the barrier path

    let dir =
        std::env::temp_dir().join(format!("deepaxe_async_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let runs = dir.join("runs");
    let fp = "it-async-resume";

    // reference: sync, unjournaled, uninterrupted
    let ref_staged = StagedEvaluator::new(&ev, mk_spec());
    let reference = run_search(&space, &spec, &StagedBackend { st: &ref_staged }, &mut NoCache);

    // sync journaled run, journal frozen at checkpoint 2 (simulated crash)
    let run_id = {
        let staged = StagedEvaluator::new(&ev, mk_spec());
        let mut journal = JournalWriter::create(&runs, fp, 1);
        let id = journal.run_id().to_string();
        journal.limit_checkpoints(2);
        journal.set_provider(&staged);
        let _ = run_search_journaled(
            &space,
            &spec,
            &StagedBackend { st: &staged },
            &mut NoCache,
            &mut journal,
        );
        id
    };

    // resume under the async runtime with 4 workers
    let staged = StagedEvaluator::new(&ev, mk_spec());
    let mut journal = JournalWriter::resume(&runs, &run_id, fp, 1).unwrap();
    assert!(journal.replaying(), "resume must start in replay mode");
    if let Some(state) = journal.eval_state() {
        staged.restore_state(state);
    }
    journal.set_provider(&staged);
    let mut aspec = spec.clone();
    aspec.sync = false;
    aspec.workers = 4;
    let resumed = run_search_journaled(
        &space,
        &aspec,
        &StagedBackend { st: &staged },
        &mut NoCache,
        &mut journal,
    );

    assert_bit_identical(&reference, &resumed, "async resume");
    assert!(resumed.executor.is_some(), "the resumed run ran on the executor");
    assert_eq!(
        staged.ledger().snapshot(),
        ref_staged.ledger().snapshot(),
        "FI ledger must restore bit-identically across execution modes"
    );
    assert_eq!(
        staged.ledger().summary(fi.n_faults),
        ref_staged.ledger().summary(fi.n_faults),
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fi_skipped_points_excluded_from_vuln_frontier() {
    // with_fi = false leaves NaN vulnerability — the frontier over
    // (util, vuln) must be empty rather than panicking, and the driver's
    // frontier falls back to (util, acc drop)
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = fi_params(4, 8, 1);
    let ev = Evaluator::new(&net, &data, &ctx.luts, 32, fi);
    let points: Vec<_> =
        (0..4u64).map(|m| ev.evaluate("mul8s_1kvp_s", m & 0b111, false)).collect();
    assert!(points.iter().all(|p| p.fault_vuln_pct.is_nan()));
    assert!(pareto_front(&points, |p| p.util_pct, |p| p.fault_vuln_pct).is_empty());

    let space = SearchSpace::paper(&net, &["mul8s_1kvp_s".to_string()]);
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 8;
    spec.with_fi = false;
    let backend = EvaluatorBackend { ev: &ev };
    let out = run_search(&space, &spec, &backend, &mut NoCache);
    assert!(!out.frontier_idx.is_empty(), "acc-drop frontier must exist without FI");
}

// ===========================================================================
// serve_ — DSE-as-a-service: shard/merge multi-process equivalence, worker
// journal resume, and the job-queue daemon, artifact-free (scripts/ci.sh
// runs these unconditionally alongside the zoo_/recovery_/async_ stages)
// ===========================================================================

/// Poll the daemon until `job` reaches a terminal state; panics on
/// `failed` so the error surfaces in the test output.
fn wait_for_job(socket: &std::path::Path, job: u64) -> deepaxe::util::json::Json {
    use deepaxe::serve::{protocol, Request};
    use deepaxe::util::json::Json;
    for _ in 0..2400 {
        let resp = protocol::call(socket, &Request::Status { job: Some(job) }).expect("status");
        assert!(protocol::is_ok(&resp), "status failed: {resp}");
        let j = resp.get("job").expect("job field");
        match j.get("state").and_then(Json::as_str) {
            Some("done") | Some("cancelled") => return j.clone(),
            Some("failed") => panic!("job {job} failed: {j}"),
            _ => std::thread::sleep(std::time::Duration::from_millis(50)),
        }
    }
    panic!("job {job} did not reach a terminal state in time");
}

#[test]
fn serve_shard_then_merge_is_bit_identical_to_single_process() {
    // the tentpole acceptance criterion: a 4-way partition of zoo-tiny's
    // 64-config space, swept by four independent workers (each with its
    // own staged evaluator — the separate-process stand-in), merges back
    // into the single-process exhaustive result bit-for-bit: points,
    // frontier, both hypervolumes, budget counters, and the summed ledger
    use deepaxe::eval::{FidelitySpec, LedgerSnapshot, StagedBackend, StagedEvaluator};
    use deepaxe::recovery::NoJournal;
    use deepaxe::serve::{merge_archives, run_shard, ShardArchive, ShardSpec};

    let bundle = deepaxe::zoo::build("zoo-tiny", 0x5A4D, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(6, 8, 0x5A4D);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi);
    let space = SearchSpace::paper(&bundle.net, &paper_mults());
    assert_eq!(space.size(), 64);
    // additive-ledger regime: trace cache off, screening off — per-shard
    // ledgers must sum exactly to the single-process ledger
    let mk_spec = || FidelitySpec { trace_cache_mb: 0, ..FidelitySpec::exact() };

    let ref_staged = StagedEvaluator::new(&ev, mk_spec());
    let mut spec = SearchSpec::new(Strategy::Exhaustive);
    spec.budget = 64;
    spec.seed = 0x5A4D;
    spec.with_fi = true;
    let reference = run_search(&space, &spec, &StagedBackend { st: &ref_staged }, &mut NoCache);
    assert_eq!(reference.evals_used, 64);
    assert!(reference.poisoned.is_empty());

    let mut archives: Vec<ShardArchive> = Vec::new();
    let mut summed = LedgerSnapshot::default();
    for i in 0..4 {
        let staged = StagedEvaluator::new(&ev, mk_spec());
        let mut archive = run_shard(
            &space,
            ShardSpec { index: i, of: 4 },
            true,
            &StagedBackend { st: &staged },
            &mut NoCache,
            &mut NoJournal,
        );
        archive.ledger = staged.ledger().snapshot();
        summed.merge(&archive.ledger);
        archives.push(archive);
    }

    let m = merge_archives(archives.clone()).expect("merge");
    assert_eq!(m.points.len(), reference.evaluated.len());
    for (a, b) in m.points.iter().zip(&reference.evaluated) {
        assert_eq!(a, b, "merged design points must be bit-identical");
    }
    assert_eq!(m.frontier_idx, reference.frontier_idx);
    assert_eq!(m.hv2d.to_bits(), reference.hypervolume().to_bits());
    assert_eq!(
        m.hv3d.to_bits(),
        deepaxe::search::hypervolume3(&reference.evaluated).to_bits()
    );
    assert_eq!(m.evals_used, reference.evals_used);
    assert_eq!(m.cache_hits, reference.cache_hits);
    assert!(m.poisoned.is_empty());
    assert_eq!(m.ledger, summed);
    assert_eq!(
        m.ledger,
        ref_staged.ledger().snapshot(),
        "shard ledgers must sum to the single-process ledger"
    );

    // archives survive the disk round-trip with the hv bits intact
    let dir = std::env::temp_dir().join(format!("deepaxe_serve_merge_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let loaded: Vec<ShardArchive> = archives
        .iter()
        .map(|a| {
            let p = dir.join(format!("shard_{}_of_{}.json", a.region.shard, a.region.of));
            a.save(&p).unwrap();
            ShardArchive::load(&p).unwrap()
        })
        .collect();
    let m2 = merge_archives(loaded).expect("merge after disk round-trip");
    assert_eq!(m2.hv2d.to_bits(), m.hv2d.to_bits());
    assert_eq!(m2.hv3d.to_bits(), m.hv3d.to_bits());
    assert_eq!(m2.frontier_idx, m.frontier_idx);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_worker_resume_is_bit_identical_and_listed() {
    // a worker killed after its first chunk checkpoint resumes its shard
    // sweep bit-identically, and `repro runs list` tracks the journal
    // through checkpointed -> complete while shrugging off garbage files
    use deepaxe::eval::{FidelitySpec, StagedBackend, StagedEvaluator};
    use deepaxe::recovery::{
        list_runs, JournalWriter, NoJournal, RunJournal, RunStatus, StateProvider,
    };
    use deepaxe::serve::{run_shard, worker_fingerprint, ShardSpec};

    let bundle = deepaxe::zoo::build("zoo-tiny", 0x5A4E, 32).unwrap();
    let luts = zoo_luts();
    let fi = fi_params(6, 8, 0x5A4E);
    let ev = Evaluator::new(&bundle.net, &bundle.data, &luts, 24, fi);
    // hardened space: 12^3 = 1728 genotypes, so shard 0/8 owns a region
    // (216) spanning several WORKER_CHUNK boundaries
    let space = SearchSpace::paper(&bundle.net, &paper_mults()).with_hardening();
    assert_eq!(space.size(), 1728);
    let shard = ShardSpec { index: 0, of: 8 };
    let region = shard.region(&space);
    assert_eq!((region.start, region.end), (0, 216));
    let mk_spec = || FidelitySpec { trace_cache_mb: 0, ..FidelitySpec::exact() };

    // unjournaled reference sweep (accuracy fidelity keeps 216 evals fast)
    let ref_staged = StagedEvaluator::new(&ev, mk_spec());
    let reference = run_shard(
        &space,
        shard,
        false,
        &StagedBackend { st: &ref_staged },
        &mut NoCache,
        &mut NoJournal,
    );
    assert_eq!(reference.evals_used, 216);
    assert!(reference.poisoned.is_empty());

    let dir = std::env::temp_dir().join(format!("deepaxe_serve_worker_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let runs = dir.join("runs");
    let wfp = worker_fingerprint("it-worker", &region);

    // journaled sweep, journal frozen at checkpoint 1 (simulated kill -9
    // after the first 64-genotype chunk)
    let run_id = {
        let staged = StagedEvaluator::new(&ev, mk_spec());
        let mut journal = JournalWriter::create(&runs, &wfp, 1);
        let id = journal.run_id().to_string();
        journal.limit_checkpoints(1);
        journal.set_provider(&staged);
        let full = run_shard(
            &space,
            shard,
            false,
            &StagedBackend { st: &staged },
            &mut NoCache,
            &mut journal,
        );
        assert_eq!(full.evals_used, 216);
        id
    };
    let listed = list_runs(&runs);
    assert_eq!(listed.len(), 1);
    assert_eq!(listed[0].run_id, run_id);
    assert_eq!(listed[0].status, RunStatus::Checkpointed);
    assert_eq!(listed[0].evals_used, 64, "journal must freeze at the first chunk boundary");
    assert_eq!(listed[0].budget, Some(216), "target parsed from the shard range");

    // resume: replay the 64 recorded events, evaluate the remaining 152
    let staged = StagedEvaluator::new(&ev, mk_spec());
    let mut journal = JournalWriter::resume(&runs, &run_id, &wfp, 1).unwrap();
    assert!(journal.replaying(), "resume must start in replay mode");
    if let Some(state) = journal.eval_state() {
        staged.restore_state(state);
    }
    journal.set_provider(&staged);
    let resumed = run_shard(
        &space,
        shard,
        false,
        &StagedBackend { st: &staged },
        &mut NoCache,
        &mut journal,
    );
    assert_eq!(resumed.evals_used, reference.evals_used);
    assert_eq!(resumed.cache_hits, reference.cache_hits);
    assert_eq!(resumed.points.len(), reference.points.len());
    for (a, b) in resumed.points.iter().zip(&reference.points) {
        assert_eq!(a, b, "resumed shard sweep must be bit-identical");
    }
    assert_eq!(staged.ledger().snapshot(), ref_staged.ledger().snapshot());

    // the finished journal now lists as complete; a garbage file in the
    // runs dir lists as stale instead of breaking the listing
    std::fs::write(runs.join("deadbeef.journal"), "not a journal\n").unwrap();
    let listed = list_runs(&runs);
    assert_eq!(listed.len(), 2);
    let by_id = |id: &str| listed.iter().find(|r| r.run_id == id).unwrap();
    assert_eq!(by_id(&run_id).status, RunStatus::Complete);
    assert_eq!(by_id("deadbeef").status, RunStatus::Stale);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_daemon_smoke_submit_status_snapshot_cancel_shutdown() {
    // the daemon lifecycle over the wire: submit two jobs on a one-runner
    // daemon, cancel the queued one immediately, watch the first complete,
    // snapshot its journal, exercise cancel-at-checkpoint on a live run,
    // then shut down cleanly
    use deepaxe::serve::{protocol, Daemon, Request, ServeConfig};
    use deepaxe::util::json::Json;

    let dir = std::env::temp_dir().join(format!("deepaxe_serve_smoke_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = ServeConfig {
        socket: dir.join("serve.sock"),
        work_dir: dir.clone(),
        max_jobs: 1,
    };
    let daemon = Daemon::start(cfg).expect("daemon start");
    let socket = daemon.socket();

    let submit = |job: &str| -> u64 {
        let req = Request::Submit { job: Json::parse(job).unwrap() };
        let resp = protocol::call(&socket, &req).expect("submit");
        assert!(protocol::is_ok(&resp), "submit failed: {resp}");
        resp.get("job").and_then(Json::as_i64).expect("job id") as u64
    };

    // a bad job is rejected over the wire, not on a runner thread
    let bad = Request::Submit {
        job: Json::parse(r#"{"net":"zoo-tiny","strategy":"warp"}"#).unwrap(),
    };
    let resp = protocol::call(&socket, &bad).unwrap();
    assert!(!protocol::is_ok(&resp), "bad strategy must be rejected: {resp}");

    let a = submit(
        r#"{"net":"zoo-tiny","seed":51966,"budget":8,"pop":4,"faults":6,"images":8,"eval_images":24,"trace_cache_mb":0}"#,
    );
    let b = submit(
        r#"{"net":"zoo-tiny","seed":51967,"budget":8,"pop":4,"faults":6,"images":8,"eval_images":24,"trace_cache_mb":0}"#,
    );
    assert_eq!((a, b), (1, 2));

    // b sits behind a on the single runner: cancel is immediate
    let resp = protocol::call(&socket, &Request::Cancel { job: b }).unwrap();
    assert!(protocol::is_ok(&resp), "{resp}");
    assert_eq!(resp.get("state").and_then(Json::as_str), Some("cancelled"));

    let done = wait_for_job(&socket, a);
    assert_eq!(done.get("state").and_then(Json::as_str), Some("done"));
    let report = done.get("report").expect("report");
    assert!(report.get("run_id").and_then(Json::as_str).is_some());
    assert_eq!(report.get("evals_used").and_then(Json::as_i64), Some(8));

    // the all-jobs view agrees, and reports the shared worker budget
    let resp = protocol::call(&socket, &Request::Status { job: None }).unwrap();
    assert!(protocol::is_ok(&resp), "{resp}");
    assert_eq!(resp.get("jobs").and_then(Json::as_arr).map(|j| j.len()), Some(2));
    assert!(resp.get("workers").and_then(|w| w.get("cap")).is_some());

    // snapshot rides the journal: the done job reads back as complete
    let resp = protocol::call(&socket, &Request::Snapshot { job: a }).unwrap();
    assert!(protocol::is_ok(&resp), "{resp}");
    assert_eq!(resp.get("status").and_then(Json::as_str), Some("complete"));
    assert_eq!(resp.get("evals_used").and_then(Json::as_i64), Some(8));
    assert_eq!(resp.get("budget").and_then(Json::as_i64), Some(8));

    // cancelling a finished job is an error, as is touching job 99
    let resp = protocol::call(&socket, &Request::Cancel { job: a }).unwrap();
    assert!(!protocol::is_ok(&resp), "{resp}");
    let resp = protocol::call(&socket, &Request::Status { job: Some(99) }).unwrap();
    assert!(!protocol::is_ok(&resp), "{resp}");

    // cancel-at-checkpoint on a live campaign: best-effort timing (the
    // job may legitimately finish first), but a cancelled run must leave
    // a resumable journal behind
    let c = submit(
        r#"{"net":"zoo-tiny","seed":51968,"budget":16,"pop":4,"faults":6,"images":8,"eval_images":24,"trace_cache_mb":0}"#,
    );
    let resp = protocol::call(&socket, &Request::Cancel { job: c }).unwrap();
    let terminal = wait_for_job(&socket, c);
    match terminal.get("state").and_then(Json::as_str) {
        Some("cancelled") => {
            assert!(protocol::is_ok(&resp), "{resp}");
            // cancelled while queued = no run-id, nothing to snapshot;
            // cancelled mid-run = the journal must end at a commit
            if terminal.get("run_id").and_then(Json::as_str).is_some() {
                let snap = protocol::call(&socket, &Request::Snapshot { job: c }).unwrap();
                assert!(protocol::is_ok(&snap), "{snap}");
                let status = snap.get("status").and_then(Json::as_str).unwrap();
                assert_ne!(status, "stale", "cancelled run must end at a committed checkpoint");
            }
        }
        Some("done") => {} // finished before the cancel landed: fine
        other => panic!("unexpected terminal state {other:?}"),
    }

    let resp = protocol::call(&socket, &Request::Shutdown).unwrap();
    assert!(protocol::is_ok(&resp), "{resp}");
    daemon.join();
    assert!(!socket.exists(), "join must remove the socket file");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_daemon_resume_after_frozen_checkpoint_matches_uninterrupted() {
    // the served crash-recovery acceptance criterion: a campaign whose
    // journal froze at checkpoint 2 (the kill -9 stand-in), resubmitted
    // with `resume`, reports byte-for-byte what an uninterrupted daemon
    // reports for the same job — run-id, counters, frontier, hv bits,
    // and the FI ledger
    use deepaxe::serve::{protocol, Daemon, Request, ServeConfig};
    use deepaxe::util::json::Json;

    let job_base = r#""net":"zoo-tiny","seed":53261,"budget":12,"pop":4,"faults":6,"images":8,"eval_images":24,"trace_cache_mb":0,"checkpoint_every":1"#;
    let run = |dir: &std::path::Path, job: String| -> Json {
        let cfg = ServeConfig {
            socket: dir.join("serve.sock"),
            work_dir: dir.to_path_buf(),
            max_jobs: 1,
        };
        let daemon = Daemon::start(cfg).expect("daemon start");
        let socket = daemon.socket();
        let req = Request::Submit { job: Json::parse(&job).unwrap() };
        let resp = protocol::call(&socket, &req).expect("submit");
        assert!(protocol::is_ok(&resp), "submit failed: {resp}");
        let id = resp.get("job").and_then(Json::as_i64).unwrap() as u64;
        let done = wait_for_job(&socket, id);
        assert_eq!(done.get("state").and_then(Json::as_str), Some("done"), "{done}");
        let resp = protocol::call(&socket, &Request::Shutdown).unwrap();
        assert!(protocol::is_ok(&resp), "{resp}");
        daemon.join();
        done.get("report").expect("report").clone()
    };

    let dir_a = std::env::temp_dir()
        .join(format!("deepaxe_serve_resume_a_{}", std::process::id()));
    let dir_b = std::env::temp_dir()
        .join(format!("deepaxe_serve_resume_b_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);

    // daemon A, run 1: completes in-process, journal frozen at checkpoint 2
    let frozen = run(&dir_a, format!(r#"{{{job_base},"limit_checkpoints":2}}"#));
    let rid = frozen.get("run_id").and_then(Json::as_str).unwrap().to_string();

    // daemon A, run 2: resume the frozen journal to completion
    let resumed = run(&dir_a, format!(r#"{{{job_base},"resume":"{rid}"}}"#));

    // daemon B: the same job uninterrupted, in a fresh work dir
    let reference = run(&dir_b, format!("{{{job_base}}}"));

    assert_eq!(
        format!("{resumed}"),
        format!("{reference}"),
        "resumed served campaign must reproduce the uninterrupted report"
    );
    assert_eq!(reference.get("run_id").and_then(Json::as_str), Some(rid.as_str()));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
