//! HLS C-emission integration test: generate C for a real configuration,
//! compile it with the host C compiler, and pin its predictions to the
//! rust engine image-for-image (the generated accelerator model is
//! bit-exact with the rest of the stack).

mod common;

use deepaxe::coordinator::hlsgen::generate_c;
use deepaxe::simnet::{Buffers, Engine};
use std::io::Write;
use std::process::Command;

#[test]
fn generated_c_matches_engine_mlp3() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let config = ["mul8s_1kvp_s", "exact", "mul8s_1kv8_s"];
    let c_src = generate_c(&net, &config, &ctx.luts);

    let dir = std::env::temp_dir().join(format!("deepaxe_hls_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("accel.c"), &c_src).unwrap();

    // driver: read raw int8 images from stdin, print predictions
    let n = 32usize;
    let il = net.input_len();
    let driver = format!(
        "#include <stdio.h>\n#include <stdint.h>\n\
         int deepaxe_infer(const int8_t *image);\n\
         int main(void) {{\n\
           static int8_t img[{il}];\n\
           for (int i = 0; i < {n}; i++) {{\n\
             if (fread(img, 1, {il}, stdin) != {il}) return 1;\n\
             printf(\"%d\\n\", deepaxe_infer(img));\n\
           }}\n\
           return 0;\n\
         }}\n"
    );
    std::fs::write(dir.join("driver.c"), driver).unwrap();

    let cc = std::env::var("CC").unwrap_or_else(|_| "cc".into());
    let status = Command::new(&cc)
        .args(["-O2", "-o"])
        .arg(dir.join("accel"))
        .arg(dir.join("accel.c"))
        .arg(dir.join("driver.c"))
        .status()
        .expect("spawning cc");
    assert!(status.success(), "C compilation failed");

    // run the compiled accelerator model on the first n test images
    let mut child = Command::new(dir.join("accel"))
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    {
        let stdin = child.stdin.as_mut().unwrap();
        for i in 0..n {
            let bytes: Vec<u8> = data.image(i).iter().map(|&v| v as u8).collect();
            stdin.write_all(&bytes).unwrap();
        }
    }
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let c_preds: Vec<usize> = String::from_utf8(out.stdout)
        .unwrap()
        .lines()
        .map(|l| l.trim().parse().unwrap())
        .collect();
    assert_eq!(c_preds.len(), n);

    // rust engine with the same mixed configuration
    let luts = vec![
        &ctx.luts["mul8s_1kvp_s"],
        &ctx.luts["exact"],
        &ctx.luts["mul8s_1kv8_s"],
    ];
    let engine = Engine::new(&net, luts);
    let mut buf = Buffers::for_net(&net);
    for i in 0..n {
        let rust_pred = engine.predict(data.image(i), None, &mut buf);
        assert_eq!(rust_pred, c_preds[i], "image {i}");
    }
}

#[test]
fn generated_c_matches_engine_lenet5_conv_path() {
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let config = vec!["mul8s_1kv9_s"; net.n_comp()];
    let c_src = generate_c(&net, &config, &ctx.luts);
    let dir = std::env::temp_dir().join(format!("deepaxe_hls5_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("accel.c"), &c_src).unwrap();
    let n = 8usize;
    let il = net.input_len();
    let driver = format!(
        "#include <stdio.h>\n#include <stdint.h>\n\
         int deepaxe_infer(const int8_t *image);\n\
         int main(void) {{ static int8_t img[{il}];\n\
           for (int i = 0; i < {n}; i++) {{\n\
             if (fread(img, 1, {il}, stdin) != {il}) return 1;\n\
             printf(\"%d\\n\", deepaxe_infer(img)); }}\n\
           return 0; }}\n"
    );
    std::fs::write(dir.join("driver.c"), driver).unwrap();
    let cc = std::env::var("CC").unwrap_or_else(|_| "cc".into());
    assert!(Command::new(&cc)
        .args(["-O2", "-o"])
        .arg(dir.join("accel"))
        .arg(dir.join("accel.c"))
        .arg(dir.join("driver.c"))
        .status()
        .unwrap()
        .success());
    let mut child = Command::new(dir.join("accel"))
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    {
        let stdin = child.stdin.as_mut().unwrap();
        for i in 0..n {
            stdin
                .write_all(&data.image(i).iter().map(|&v| v as u8).collect::<Vec<u8>>())
                .unwrap();
        }
    }
    let out = child.wait_with_output().unwrap();
    let c_preds: Vec<usize> =
        String::from_utf8(out.stdout).unwrap().lines().map(|l| l.parse().unwrap()).collect();
    let kv9 = &ctx.luts["mul8s_1kv9_s"];
    let engine = Engine::uniform(&net, kv9);
    let mut buf = Buffers::for_net(&net);
    for i in 0..n {
        assert_eq!(engine.predict(data.image(i), None, &mut buf), c_preds[i], "image {i}");
    }
}
