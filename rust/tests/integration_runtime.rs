//! PJRT runtime vs simnet vs python: all three implementations of the
//! quantized network must agree bit-for-bit.

mod common;

use deepaxe::axmul::Lut;
use deepaxe::nbin::Nbin;
use deepaxe::runtime::Runtime;
use deepaxe::simnet::{Buffers, Engine, FaultSite};

#[test]
fn pjrt_matches_python_and_simnet_mlp3() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let batch = ctx.lower_batch();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_net(&ctx.artifacts, &net, batch).unwrap();

    let exp = Nbin::read_file(common::artifacts().join("mlp3.expected.nbin")).unwrap();
    let pred_exact = exp.get_i32("pred_exact").unwrap();
    let n = pred_exact.len();

    let exact = &ctx.luts["exact"];
    let luts: Vec<&Lut> = (0..net.n_comp()).map(|_| exact).collect();
    let pjrt = exe.predict_all(&data.take(n), &luts, None).unwrap();
    for i in 0..n {
        assert_eq!(pjrt[i] as i32, pred_exact[i], "pjrt vs python, image {i}");
    }

    // approximate configuration
    let kvp = &ctx.luts["mul8s_1kvp_s"];
    let luts_kvp: Vec<&Lut> = (0..net.n_comp()).map(|_| kvp).collect();
    let pred_axm = exp.get_i32("pred_axm_kvp").unwrap();
    let pjrt_axm = exe.predict_all(&data.take(n), &luts_kvp, None).unwrap();
    for i in 0..n {
        assert_eq!(pjrt_axm[i] as i32, pred_axm[i], "pjrt axm vs python, image {i}");
    }
}

#[test]
fn pjrt_fault_injection_matches_python_mlp3() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_net(&ctx.artifacts, &net, ctx.lower_batch()).unwrap();

    let exp = Nbin::read_file(common::artifacts().join("mlp3.expected.nbin")).unwrap();
    let sites = exp.get_i32("fault_sites").unwrap();
    let preds = exp.get_i32("pred_fault").unwrap();
    let n_cases = exp.get("fault_sites").unwrap().dims[0];
    let n_img = exp.get("pred_fault").unwrap().dims[1];

    let exact = &ctx.luts["exact"];
    let luts: Vec<&Lut> = (0..net.n_comp()).map(|_| exact).collect();
    for f in 0..n_cases {
        let site = FaultSite {
            layer: sites[f * 3] as usize,
            neuron: sites[f * 3 + 1] as usize,
            bit: sites[f * 3 + 2] as u8,
        };
        let got = exe.predict_all(&data.take(n_img), &luts, Some(site)).unwrap();
        for i in 0..n_img {
            assert_eq!(got[i] as i32, preds[f * n_img + i], "fault {site:?} image {i}");
        }
    }
}

#[test]
fn pjrt_matches_simnet_lenet5_mixed_config() {
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_net(&ctx.artifacts, &net, ctx.lower_batch()).unwrap();

    // mixed per-layer configuration: kv9 on conv layers, exact on dense
    let exact = &ctx.luts["exact"];
    let kv9 = &ctx.luts["mul8s_1kv9_s"];
    let luts: Vec<&Lut> =
        (0..net.n_comp()).map(|ci| if ci < 2 { kv9 } else { exact }).collect();

    let n = 32;
    let pjrt = exe.predict_all(&data.take(n), &luts, None).unwrap();
    let engine = Engine::new(&net, luts.clone());
    let mut buf = Buffers::for_net(&net);
    for i in 0..n {
        let simnet = engine.predict(data.image(i), None, &mut buf);
        assert_eq!(simnet, pjrt[i], "image {i}");
    }
}
