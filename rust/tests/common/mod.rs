//! Shared helpers for integration tests (require `make artifacts`).
#![allow(dead_code)] // not every test binary uses every helper

use deepaxe::coordinator::Ctx;
use std::path::PathBuf;

/// Artifacts dir for tests: CARGO_MANIFEST_DIR/artifacts.
pub fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

pub fn ensure_artifacts() {
    let a = artifacts();
    assert!(
        a.join("manifest.json").exists(),
        "artifacts missing at {} — run `make artifacts` first",
        a.display()
    );
    std::env::set_var("DEEPAXE_ARTIFACTS", a.to_str().unwrap());
}

pub fn ctx() -> Ctx {
    ensure_artifacts();
    Ctx::load().expect("loading context")
}
