//! DSE orchestration on the real artifacts: evaluator, sweep + cache,
//! pipeline, and the paper's qualitative trends.

mod common;

use deepaxe::coordinator::jobs::{run_sweep, SweepSpec};
use deepaxe::coordinator::pipeline::{run_pipeline, PipelineSpec};
use deepaxe::dse::cache::ResultCache;
use deepaxe::dse::{enumerate_masks, pareto_front, Evaluator};
use deepaxe::faultsim::{CampaignParams, SiteSampling};

#[test]
fn evaluator_trends_mlp3() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = CampaignParams {
        n_faults: 16,
        n_images: 24,
        seed: 7,
        workers: 2,
        sampling: SiteSampling::UniformLayer,
        replay: true,
        gate: true,
        delta: true,
        batch: true,
    };
    let ev = Evaluator::new(&net, &data, &ctx.luts, 500, fi);
    // exact config: no accuracy drop by definition
    let exact = ev.evaluate("exact", 0, false);
    assert!(exact.acc_drop_pct.abs() < 1e-9);
    // full kvp approximation drops more than (or equal to) full kv8, up to
    // subset noise (approximation can even help slightly on easy subsets)
    let kvp = ev.evaluate("mul8s_1kvp_s", 0b111, false);
    let kv8 = ev.evaluate("mul8s_1kv8_s", 0b111, false);
    assert!(kvp.acc_drop_pct >= kv8.acc_drop_pct - 1.0, "kvp {} kv8 {}", kvp.acc_drop_pct, kv8.acc_drop_pct);
    assert!(kvp.acc_drop_pct.abs() < 30.0 && kv8.acc_drop_pct.abs() < 30.0);
    // hardware: full approximation cheaper than exact
    assert!(kvp.cycles < exact.cycles);
    assert!(kvp.util_pct < exact.util_pct);
}

#[test]
fn sweep_cache_roundtrip() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = CampaignParams {
        n_faults: 8,
        n_images: 16,
        seed: 9,
        workers: 1,
        sampling: SiteSampling::UniformLayer,
        replay: true,
        gate: true,
        delta: true,
        batch: true,
    };
    let ev = Evaluator::new(&net, &data, &ctx.luts, 64, fi);
    let dir = std::env::temp_dir().join(format!("deepaxe_dse_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cache_path = dir.join("results.jsonl");
    let _ = std::fs::remove_file(&cache_path);

    let spec = SweepSpec {
        mults: vec!["mul8s_1kvp_s", "mul8s_1kv8_s"],
        masks: enumerate_masks(net.n_comp()),
        with_fi: false,
    };
    let mut cache = ResultCache::open(&cache_path);
    let t0 = std::time::Instant::now();
    let pts = run_sweep(&ev, &mut cache, &spec).unwrap();
    let cold = t0.elapsed();
    assert_eq!(pts.len(), spec.n_points());

    // second run: everything from cache (a fresh cache object re-reads the
    // file, proving persistence), and much faster
    let mut cache2 = ResultCache::open(&cache_path);
    assert_eq!(cache2.len(), pts.len());
    let t1 = std::time::Instant::now();
    let pts2 = run_sweep(&ev, &mut cache2, &spec).unwrap();
    let warm = t1.elapsed();
    assert_eq!(pts.len(), pts2.len());
    for (a, b) in pts.iter().zip(&pts2) {
        // NaN-tolerant comparison (FI fields are NaN when FI is skipped)
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
    assert!(warm < cold, "warm {warm:?} !< cold {cold:?}");
}

#[test]
fn pareto_front_on_real_sweep() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let fi = CampaignParams {
        n_faults: 8,
        n_images: 16,
        seed: 9,
        workers: 1,
        sampling: SiteSampling::UniformLayer,
        replay: true,
        gate: true,
        delta: true,
        batch: true,
    };
    let ev = Evaluator::new(&net, &data, &ctx.luts, 100, fi);
    let pts: Vec<_> = enumerate_masks(3)
        .into_iter()
        .map(|m| ev.evaluate("mul8s_1kvp_s", m, false))
        .collect();
    let front = pareto_front(&pts, |p| p.util_pct, |p| p.acc_drop_pct);
    assert!(!front.is_empty());
    // the fully-approximated config has minimal utilization, so it must be
    // on the frontier
    let full_idx = pts.iter().position(|p| p.mask == 0b111).unwrap();
    assert!(front.contains(&full_idx));
}

#[test]
fn pipeline_selects_feasible_design() {
    let ctx = common::ctx();
    let spec = PipelineSpec {
        net: "mlp3".into(),
        mults: vec!["mul8s_1kvp_s".into(), "mul8s_1kv8_s".into()],
        max_acc_drop_pct: 50.0,
        max_vuln_pct: 100.0,
        eval_images: 64,
        fi: CampaignParams {
            n_faults: 8,
            n_images: 16,
            seed: 11,
            workers: 1,
            sampling: SiteSampling::UniformLayer,
            replay: true,
            gate: true,
            delta: true,
            batch: true,
        },
        strategy: deepaxe::search::Strategy::Exhaustive,
        budget: 0,
        fi_epsilon: 0.0,
        fi_screen: 0,
        fi_screen_auto: false,
    };
    let out = run_pipeline(&ctx, &spec).unwrap();
    assert_eq!(out.accuracy_sweep.len(), 2 * 7 + 1); // 2 mults x 7 nonzero masks + exact
    assert!(!out.fi_points.is_empty());
    let sel = out.selected.expect("a design must be selected under loose constraints");
    // selected point is utilization-minimal among feasible
    for p in &out.feasible {
        assert!(sel.util_pct <= p.util_pct + 1e-12);
    }
    // Leveugle sizing is bounded by the fault population and substantial
    let net = ctx.net("mlp3").unwrap();
    let population = deepaxe::faultsim::fault_population(&net);
    assert!(out.required_faults > population / 2 && out.required_faults <= population);
}

#[test]
fn pipeline_infeasible_requirements() {
    let ctx = common::ctx();
    let spec = PipelineSpec {
        net: "mlp3".into(),
        mults: vec!["mul8s_1kvp_s".into()],
        max_acc_drop_pct: -1000.0, // impossible (drop is bounded by [-100, 100])
        max_vuln_pct: 0.0,
        eval_images: 32,
        fi: CampaignParams {
            n_faults: 4,
            n_images: 8,
            seed: 11,
            workers: 1,
            sampling: SiteSampling::UniformLayer,
            replay: true,
            gate: true,
            delta: true,
            batch: true,
        },
        strategy: deepaxe::search::Strategy::Exhaustive,
        budget: 0,
        fi_epsilon: 0.0,
        fi_screen: 0,
        fi_screen_auto: false,
    };
    let out = run_pipeline(&ctx, &spec).unwrap();
    assert!(out.fi_points.is_empty());
    assert!(out.selected.is_none());
}
