//! Fault campaigns on the real artifacts.

mod common;

use deepaxe::faultsim::{run_campaign, CampaignParams, SiteSampling};
use deepaxe::simnet::Engine;

fn params(n_faults: usize, n_images: usize, replay: bool) -> CampaignParams {
    CampaignParams {
        n_faults,
        n_images,
        seed: 0x5EED,
        workers: 2,
        sampling: SiteSampling::UniformLayer,
        replay,
    }
}

#[test]
fn replay_equals_naive_on_real_net() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let fast = run_campaign(&engine, &data, &params(24, 20, true));
    let slow = run_campaign(&engine, &data, &params(24, 20, false));
    assert_eq!(fast.acc_per_fault, slow.acc_per_fault);
    assert_eq!(fast.base_acc, slow.base_acc);
}

#[test]
fn campaign_metrics_sane() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let r = run_campaign(&engine, &data, &params(60, 60, true));
    assert!(r.base_acc > 0.6, "base acc {}", r.base_acc);
    // faults can only hurt on average (masking can help individual images,
    // but the mean over random single-bit flips must not *gain* much)
    assert!(r.mean_fault_acc <= r.base_acc + 0.02);
    assert!(r.vulnerability > -0.02);
    assert_eq!(r.acc_per_fault.len(), 60);
    assert!(r.ci95 > 0.0 && r.ci95 < 0.2);
}

#[test]
fn high_bits_hurt_more_than_low_bits() {
    // Flipping bit 7 (sign) of a mid-network activation should be at least
    // as damaging on average as flipping bit 0.
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap().take(80);
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let mut buf = deepaxe::simnet::Buffers::for_net(&net);
    let mut acc = [0.0f64; 2];
    for (bi, bit) in [0u8, 7].iter().enumerate() {
        let mut correct = 0usize;
        let mut total = 0usize;
        for neuron in [0usize, 7, 19, 31, 44, 63] {
            let site = deepaxe::simnet::FaultSite { layer: 0, neuron, bit: *bit };
            for i in 0..data.len() {
                if engine.predict(data.image(i), Some(site), &mut buf)
                    == data.labels[i] as usize
                {
                    correct += 1;
                }
                total += 1;
            }
        }
        acc[bi] = correct as f64 / total as f64;
    }
    assert!(acc[1] <= acc[0] + 0.01, "bit7 acc {} vs bit0 acc {}", acc[1], acc[0]);
}

#[test]
fn approximated_network_campaign_runs() {
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["mul8s_1kvp_s"]);
    let r = run_campaign(&engine, &data, &params(30, 30, true));
    assert!(r.base_acc > 0.5);
    assert!(r.mean_fault_acc > 0.0 && r.mean_fault_acc <= 1.0);
}
