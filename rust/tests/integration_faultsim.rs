//! Fault campaigns on the real artifacts — plus `zoo_`-prefixed variants
//! on generated networks that need **no artifacts at all** (these are the
//! tests `scripts/ci.sh` runs unconditionally).

mod common;

use deepaxe::faultsim::{run_campaign, sample_sites, CampaignParams, SiteSampling};
use deepaxe::simnet::Engine;
use deepaxe::util::rng::Rng;

fn params(n_faults: usize, n_images: usize, replay: bool) -> CampaignParams {
    CampaignParams {
        n_faults,
        n_images,
        seed: 0x5EED,
        workers: 2,
        sampling: SiteSampling::UniformLayer,
        replay,
        gate: true,
        delta: true,
        batch: true,
    }
}

#[test]
fn replay_equals_naive_on_real_net() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let fast = run_campaign(&engine, &data, &params(24, 20, true));
    let slow = run_campaign(&engine, &data, &params(24, 20, false));
    assert_eq!(fast.acc_per_fault, slow.acc_per_fault);
    assert_eq!(fast.base_acc, slow.base_acc);
}

#[test]
fn convergence_gate_bit_identical_on_real_nets() {
    // the PR 3 acceptance criterion on real artifacts: gated replay ==
    // ungated replay == naive forwards, for exact and approximated
    // configurations, with the gate's savings visible in the stats
    let ctx = common::ctx();
    for (net_name, mult) in [("mlp3", "exact"), ("lenet5", "mul8s_1kvp_s")] {
        let net = ctx.net(net_name).unwrap();
        let data = ctx.data_for(&net).unwrap();
        let engine = Engine::uniform(&net, &ctx.luts[mult]);
        let gated = run_campaign(&engine, &data, &params(24, 20, true));
        let mut off = params(24, 20, true);
        off.gate = false;
        let ungated = run_campaign(&engine, &data, &off);
        let naive = run_campaign(&engine, &data, &params(24, 20, false));
        assert_eq!(gated.acc_per_fault, ungated.acc_per_fault, "{net_name}");
        assert_eq!(gated.acc_per_fault, naive.acc_per_fault, "{net_name}");
        assert_eq!(gated.mean_fault_acc, naive.mean_fault_acc, "{net_name}");
        assert_eq!(gated.ci95, naive.ci95, "{net_name}");
        // same inferences, never more re-simulated layers
        assert_eq!(gated.replay.inferences, ungated.replay.inferences);
        assert!(gated.replay.replayed_layers <= ungated.replay.replayed_layers);
        assert_eq!(gated.replay.depth_hist.iter().sum::<u64>(), gated.replay.inferences);
    }
}

#[test]
fn delta_replay_bit_identical_on_real_nets() {
    // the PR 4 acceptance criterion on real artifacts: with DEEPAXE_NO_DELTA
    // unset vs set (params.delta on/off), campaign results — vulnerability,
    // masked counts, preds, the whole ReplayStats — are equal, and the
    // delta path actually served patchable faults
    let ctx = common::ctx();
    for (net_name, mult) in [("mlp3", "exact"), ("lenet5", "mul8s_1kvp_s")] {
        let net = ctx.net(net_name).unwrap();
        let data = ctx.data_for(&net).unwrap();
        let engine = Engine::uniform(&net, &ctx.luts[mult]);
        let on = run_campaign(&engine, &data, &params(24, 20, true));
        let mut p_off = params(24, 20, true);
        p_off.delta = false;
        let off = run_campaign(&engine, &data, &p_off);
        let naive = run_campaign(&engine, &data, &params(24, 20, false));
        assert_eq!(on.acc_per_fault, off.acc_per_fault, "{net_name}");
        assert_eq!(on.acc_per_fault, naive.acc_per_fault, "{net_name}");
        assert_eq!(on.mean_fault_acc, off.mean_fault_acc, "{net_name}");
        assert_eq!(on.vulnerability, off.vulnerability, "{net_name}");
        assert_eq!(on.ci95, off.ci95, "{net_name}");
        assert_eq!(on.base_acc, off.base_acc, "{net_name}");
        assert_eq!(on.replay, off.replay, "{net_name}: replay stats must not move");
        assert!(on.delta_replays > 0, "{net_name}: delta path must serve faults");
        assert_eq!(off.delta_replays, 0, "{net_name}");
    }
}

#[test]
fn campaign_metrics_sane() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let r = run_campaign(&engine, &data, &params(60, 60, true));
    assert!(r.base_acc > 0.6, "base acc {}", r.base_acc);
    // faults can only hurt on average (masking can help individual images,
    // but the mean over random single-bit flips must not *gain* much)
    assert!(r.mean_fault_acc <= r.base_acc + 0.02);
    assert!(r.vulnerability > -0.02);
    assert_eq!(r.acc_per_fault.len(), 60);
    assert!(r.ci95 > 0.0 && r.ci95 < 0.2);
}

#[test]
fn high_bits_hurt_more_than_low_bits() {
    // Flipping bit 7 (sign) of a mid-network activation should be at least
    // as damaging on average as flipping bit 0.
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap().take(80);
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let mut buf = deepaxe::simnet::Buffers::for_net(&net);
    let mut acc = [0.0f64; 2];
    for (bi, bit) in [0u8, 7].iter().enumerate() {
        let mut correct = 0usize;
        let mut total = 0usize;
        for neuron in [0usize, 7, 19, 31, 44, 63] {
            let site = deepaxe::simnet::FaultSite { layer: 0, neuron, bit: *bit };
            for i in 0..data.len() {
                if engine.predict(data.image(i), Some(site), &mut buf)
                    == data.labels[i] as usize
                {
                    correct += 1;
                }
                total += 1;
            }
        }
        acc[bi] = correct as f64 / total as f64;
    }
    assert!(acc[1] <= acc[0] + 0.01, "bit7 acc {} vs bit0 acc {}", acc[1], acc[0]);
}

#[test]
fn approximated_network_campaign_runs() {
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["mul8s_1kvp_s"]);
    let r = run_campaign(&engine, &data, &params(30, 30, true));
    assert!(r.base_acc > 0.5);
    assert!(r.mean_fault_acc > 0.0 && r.mean_fault_acc <= 1.0);
}

// ===========================================================================
// zoo_ — artifact-free campaigns on generated networks
// ===========================================================================

#[test]
fn zoo_delta_and_gate_bit_identical_on_generated_conv_net() {
    // the delta/gate parity suite on a zoo conv net: no common::ctx(),
    // no manifest — this runs in every container
    let net = deepaxe::zoo::build_net("zoo-tiny", 0xA5).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 24, 0xA5);
    for mult in ["exact", "mul8s_1kvp_s"] {
        let lut = deepaxe::axmul::by_name(mult).unwrap().lut();
        let engine = Engine::uniform(&net, &lut);
        let on = run_campaign(&engine, &data, &params(24, 16, true));
        let mut p_nodelta = params(24, 16, true);
        p_nodelta.delta = false;
        let nodelta = run_campaign(&engine, &data, &p_nodelta);
        let mut p_nogate = p_nodelta.clone();
        p_nogate.gate = false;
        let nogate = run_campaign(&engine, &data, &p_nogate);
        let naive = run_campaign(&engine, &data, &params(24, 16, false));
        assert_eq!(on.acc_per_fault, nodelta.acc_per_fault, "{mult}: delta must not move results");
        assert_eq!(on.acc_per_fault, nogate.acc_per_fault, "{mult}: gate must not move results");
        assert_eq!(on.acc_per_fault, naive.acc_per_fault, "{mult}: replay == naive");
        assert_eq!(on.vulnerability, naive.vulnerability, "{mult}");
        assert_eq!(on.ci95, naive.ci95, "{mult}");
        assert!(on.delta_replays > 0, "{mult}: conv fault sites must take the delta path");
        assert_eq!(nodelta.delta_replays, 0, "{mult}");
    }
}

#[test]
fn zoo_campaign_vulnerability_is_nonnegative_on_teacher_labels() {
    // teacher-labeled data puts the exact engine at 100%: any injected
    // fault can only lose agreement, so vulnerability >= 0 exactly
    let net = deepaxe::zoo::build_net("zoo-tiny", 0x77).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 32, 0x77);
    let lut = deepaxe::axmul::by_name("exact").unwrap().lut();
    let engine = Engine::uniform(&net, &lut);
    let r = run_campaign(&engine, &data, &params(40, 24, true));
    assert_eq!(r.base_acc, 1.0, "exact engine on its own labels");
    assert!(r.vulnerability >= 0.0, "{}", r.vulnerability);
    assert!(r.mean_fault_acc <= 1.0);
    assert_eq!(r.acc_per_fault.len(), 40);
}

#[test]
fn zoo_site_sampling_covers_deep_topologies() {
    // site sampling over a 12-computing-layer zoo net: every site in
    // bounds, both modes deterministic, and UniformLayer actually reaches
    // the deep tail of the network
    let net = deepaxe::zoo::build_net("mlp-deep-12", 1).unwrap();
    assert_eq!(net.n_comp(), 12);
    for mode in [SiteSampling::UniformLayer, SiteSampling::UniformNeuron] {
        let a = sample_sites(&net, 1200, mode, &mut Rng::new(9));
        let b = sample_sites(&net, 1200, mode, &mut Rng::new(9));
        assert_eq!(a, b, "{mode:?} must be deterministic");
        for s in &a {
            assert!(s.layer < net.n_comp());
            assert!(s.neuron < net.comp(s.layer).act_len());
            assert!(s.bit < 8);
        }
        if mode == SiteSampling::UniformLayer {
            let mut hit = vec![false; net.n_comp()];
            for s in &a {
                hit[s.layer] = true;
            }
            assert!(hit.iter().all(|&h| h), "1200 uniform-layer draws must hit all 12 layers");
        }
    }
}

// ===========================================================================
// zoo_batch_ — batch-major engine path parity (PR 7; artifact-free, runs
// under the zoo_ filter in ci.sh)
// ===========================================================================

#[test]
fn zoo_batch_forward_bit_identical_across_batch_sizes_and_simd() {
    // satellite: batch forward == per-image forward, bit for bit, across
    // generated nets, batch sizes {1, 7, 64, n}, and SIMD on/off (set_simd
    // is a no-op returning the scalar path on toolchains without the
    // `simd` feature, so both iterations are exercised either way)
    use deepaxe::simnet::{set_simd, Batch, Buffers};
    for (spec, seed) in [("zoo-tiny", 0xA5u64), ("zoo-tiny", 0x3C), ("mlp-deep-12", 7)] {
        let net = deepaxe::zoo::build_net(spec, seed).unwrap();
        let data = deepaxe::zoo::synth_dataset(&net, 19, seed);
        let n = data.len();
        let sz = data.image_len();
        let lut = deepaxe::axmul::by_name("mul8s_1kvp_s").unwrap().lut();
        let engine = Engine::uniform(&net, &lut);
        let mut buf = Buffers::for_net(&net);
        let reference: Vec<usize> =
            (0..n).map(|i| engine.predict(data.image(i), None, &mut buf)).collect();
        for simd in [false, true] {
            let prev = set_simd(simd);
            for bsz in [1usize, 7, 64, n] {
                let cap = bsz.min(n);
                let mut bt = Batch::for_net(&net, cap);
                let mut preds = Vec::new();
                let mut got = Vec::with_capacity(n);
                let mut i = 0;
                while i < n {
                    let m = cap.min(n - i);
                    engine.predict_batch(&data.x.data[i * sz..(i + m) * sz], &mut bt, &mut preds);
                    got.extend_from_slice(&preds);
                    i += m;
                }
                assert_eq!(got, reference, "{spec}/{seed:x} bsz={bsz} simd={simd}");
            }
            set_simd(prev);
        }
    }
}

#[test]
fn zoo_batch_campaign_bit_identical_with_stats_and_simd() {
    // satellite: fault-major group replay (batch on) == image-major
    // campaign (batch off) == the same with SIMD toggled — per-fault
    // accuracies AND the full ReplayStats AND the delta-serve counts
    // (servability is image-independent, so fault-major groups serve
    // exactly the faults the per-image delta path serves)
    use deepaxe::simnet::set_simd;
    let net = deepaxe::zoo::build_net("zoo-tiny", 0xBA).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 24, 0xBA);
    let lut = deepaxe::axmul::by_name("mul8s_1kvp_s").unwrap().lut();
    let engine = Engine::uniform(&net, &lut);
    let p = params(32, 16, true);
    let mut p_off = p.clone();
    p_off.batch = false;
    let reference = run_campaign(&engine, &data, &p_off);
    for simd in [false, true] {
        let prev = set_simd(simd);
        let batched = run_campaign(&engine, &data, &p);
        let scalar = run_campaign(&engine, &data, &p_off);
        set_simd(prev);
        for (label, r) in [("batch", &batched), ("scalar", &scalar)] {
            assert_eq!(r.acc_per_fault, reference.acc_per_fault, "{label} simd={simd}");
            assert_eq!(r.base_acc, reference.base_acc, "{label} simd={simd}");
            assert_eq!(r.replay, reference.replay, "{label} simd={simd}: stats must not move");
            assert_eq!(r.delta_replays, reference.delta_replays, "{label} simd={simd}");
        }
        assert!(batched.delta_replays > 0, "conv sites must take the group-delta path");
    }
}

// ===========================================================================
// fault_model_ — the unified fault-model zoo (artifact-free; ci.sh runs
// these unconditionally alongside the zoo_ suite)
// ===========================================================================

use deepaxe::faultsim::{run_model_campaign, sample_model_faults, FaultModelKind};

#[test]
fn fault_model_bitflip_is_bit_for_bit_the_legacy_runner() {
    // acceptance criterion: the default bitflip model reproduces the
    // pre-zoo campaign exactly — per-fault accuracies, summary stats, and
    // the whole ReplayStats — on both an exact and an approximated engine
    let net = deepaxe::zoo::build_net("zoo-tiny", 0xA5).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 24, 0xA5);
    for mult in ["exact", "mul8s_1kvp_s"] {
        let lut = deepaxe::axmul::by_name(mult).unwrap().lut();
        let engine = Engine::uniform(&net, &lut);
        let p = params(32, 16, true);
        let legacy = run_campaign(&engine, &data, &p);
        let model = run_model_campaign(FaultModelKind::BitFlip, &engine, &data, &p);
        assert_eq!(model.acc_per_fault, legacy.acc_per_fault, "{mult}");
        assert_eq!(model.base_acc, legacy.base_acc, "{mult}");
        assert_eq!(model.mean_fault_acc, legacy.mean_fault_acc, "{mult}");
        assert_eq!(model.vulnerability, legacy.vulnerability, "{mult}");
        assert_eq!(model.ci95, legacy.ci95, "{mult}");
        assert_eq!(model.replay, legacy.replay, "{mult}: ReplayStats must be identical");
        assert_eq!(model.delta_replays, legacy.delta_replays, "{mult}");
    }
}

#[test]
fn fault_model_sampling_shares_sites_per_seed() {
    // the comparability contract: every activation model under the same
    // (net, n, sampling, seed) faults exactly the same sites — only the
    // perturbations differ
    let net = deepaxe::zoo::build_net("zoo-tiny", 0x77).unwrap();
    let baseline = sample_sites(&net, 40, SiteSampling::UniformLayer, &mut Rng::new(0x5EED));
    for kind in [FaultModelKind::BitFlip, FaultModelKind::StuckAt, FaultModelKind::MultiBit] {
        let mut rng = Rng::new(0x5EED);
        let (sites, perturbs) =
            sample_model_faults(&net, 40, SiteSampling::UniformLayer, &mut rng, kind);
        assert_eq!(sites, baseline, "{kind:?}");
        assert_eq!(perturbs.len(), 40, "{kind:?}");
    }
    // multibit bursts request 2-4 adjacent bits (clipped at the byte edge)
    let mut rng = Rng::new(0x5EED);
    let (_, perturbs) =
        sample_model_faults(&net, 40, SiteSampling::UniformLayer, &mut rng, FaultModelKind::MultiBit);
    assert!(perturbs.iter().all(|p| (1..=4).contains(&p.width())));
    assert!(perturbs.iter().any(|p| p.width() >= 2), "bursts must exist");
}

#[test]
fn fault_model_stuckat_wraps_the_permanent_campaign() {
    // run_stuck_campaign is now a thin wrapper over the model dispatch —
    // both spellings must agree fault for fault (per-fault accuracies are
    // invariant to workers/replay/gate/delta, so the wrapper's env-driven
    // params cannot move them)
    let net = deepaxe::zoo::build_net("zoo-tiny", 0xA5).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 24, 0xA5);
    let lut = deepaxe::axmul::by_name("exact").unwrap().lut();
    let engine = Engine::uniform(&net, &lut);
    let model = run_model_campaign(FaultModelKind::StuckAt, &engine, &data, &params(24, 16, true));
    let wrapper = deepaxe::faultsim::run_stuck_campaign(
        &engine,
        &data,
        24,
        16,
        0x5EED,
        SiteSampling::UniformLayer,
    );
    assert_eq!(model.acc_per_fault, wrapper.acc_per_fault);
    assert_eq!(model.base_acc, wrapper.base_acc);
    assert_eq!(model.vulnerability, wrapper.vulnerability);
    assert_eq!(model.ci95, wrapper.ci95);
}

#[test]
fn fault_model_lutplane_on_teacher_labels() {
    // teacher labels put the exact engine at 100%: a stuck LUT bit-plane
    // can only lose agreement, so vulnerability >= 0 exactly; the campaign
    // is deterministic across runs
    let net = deepaxe::zoo::build_net("zoo-tiny", 0x77).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 32, 0x77);
    let lut = deepaxe::axmul::by_name("exact").unwrap().lut();
    let engine = Engine::uniform(&net, &lut);
    let p = params(24, 16, true);
    let a = run_model_campaign(FaultModelKind::LutPlane, &engine, &data, &p);
    let b = run_model_campaign(FaultModelKind::LutPlane, &engine, &data, &p);
    assert_eq!(a.acc_per_fault, b.acc_per_fault, "lutplane campaigns must be deterministic");
    assert_eq!(a.base_acc, 1.0, "exact engine on its own labels");
    assert!(a.vulnerability >= 0.0, "{}", a.vulnerability);
    assert!(a.mean_fault_acc <= 1.0);
    assert_eq!(a.n_faults, 24);
}

#[test]
fn fault_model_multibit_hurts_at_least_as_much_as_bitflip() {
    // a burst flips the bitflip site's bit plus up to 3 neighbours — on
    // teacher-labeled data (base 100%) the mean damage over the shared
    // site list should not be *less* than single-bit flips by more than
    // noise; assert the weak direction that holds by construction:
    // determinism + shared base
    let net = deepaxe::zoo::build_net("zoo-tiny", 0x77).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 32, 0x77);
    let lut = deepaxe::axmul::by_name("exact").unwrap().lut();
    let engine = Engine::uniform(&net, &lut);
    let p = params(40, 24, true);
    let flip = run_model_campaign(FaultModelKind::BitFlip, &engine, &data, &p);
    let burst = run_model_campaign(FaultModelKind::MultiBit, &engine, &data, &p);
    assert_eq!(flip.base_acc, burst.base_acc);
    assert_eq!(burst.acc_per_fault.len(), 40);
    assert!(burst.vulnerability >= 0.0);
    // deterministic: a second run is identical
    let again = run_model_campaign(FaultModelKind::MultiBit, &engine, &data, &p);
    assert_eq!(burst.acc_per_fault, again.acc_per_fault);
}

#[test]
fn fault_model_hardening_masks_through_staged_evaluator() {
    // selective hardening end-to-end: TMR everywhere drives vulnerability
    // to zero and charges area/power, without touching the schedule
    use deepaxe::dse::Evaluator;
    use deepaxe::eval::{Fidelity, FidelitySpec, StagedEvaluator};
    let net = deepaxe::zoo::build_net("zoo-tiny", 0xA5).unwrap();
    let data = deepaxe::zoo::synth_dataset(&net, 32, 0xA5);
    let luts: std::collections::BTreeMap<String, deepaxe::axmul::Lut> =
        deepaxe::axmul::CATALOG.iter().map(|m| (m.name.to_string(), m.lut())).collect();
    let ev = Evaluator::new(&net, &data, &luts, 32, params(32, 16, true));
    let st = StagedEvaluator::new(&ev, FidelitySpec::exact());
    let n = net.n_comp();
    let plain: Vec<&str> = vec!["exact"; n];
    let mut tmr = plain.clone();
    tmr.extend(std::iter::repeat("tmr").take(n));
    let p = st.evaluate(&plain, Fidelity::FiFull, None);
    let h = st.evaluate(&tmr, Fidelity::FiFull, None);
    assert!(h.fault_vuln_pct.abs() < 1e-9, "{}", h.fault_vuln_pct);
    assert!(p.fault_vuln_pct >= 0.0);
    assert!(h.luts > p.luts && h.power_mw > p.power_mw);
    assert_eq!(h.cycles, p.cycles, "hardening must not change the schedule");
}
