//! End-to-end CLI tests: run the `repro` binary against the artifacts.
//! The `zoo_`-prefixed tests run the binary with **no artifacts** (from a
//! temp cwd) — the zoo subcommands and `exp zoo-sweep` must work in any
//! container.

mod common;

use std::process::Command;

fn repro(args: &[&str]) -> std::process::Output {
    common::ensure_artifacts();
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .env("DEEPAXE_ARTIFACTS", common::artifacts())
        .env("DEEPAXE_QUIET", "1")
        .output()
        .expect("spawning repro")
}

/// Run `repro` from an empty temp directory with no artifacts reachable.
fn repro_no_artifacts(args: &[&str]) -> std::process::Output {
    let dir = std::env::temp_dir().join(format!("deepaxe_zoo_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp cwd");
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .current_dir(&dir)
        .env("DEEPAXE_ARTIFACTS", dir.join("no-artifacts-here"))
        .env("DEEPAXE_QUIET", "1")
        .output()
        .expect("spawning repro")
}

#[test]
fn help_prints_usage() {
    let out = repro(&["--help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("pipeline"));
}

#[test]
fn info_lists_model_zoo() {
    let out = repro(&["info"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for net in ["mlp3", "mlp5", "mlp7", "lenet5", "alexnet"] {
        assert!(text.contains(net), "missing {net}: {text}");
    }
}

#[test]
fn faults_prints_leveugle_sizing() {
    let out = repro(&["faults"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Leveugle"));
    assert!(text.contains("mlp3"));
}

#[test]
fn eval_single_config() {
    let out = repro(&[
        "eval", "--net", "mlp3", "--mult", "kvp", "--config", "101",
        "--fi", "--faults", "6", "--images", "12", "--eval-images", "40",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("acc drop pp"));
    assert!(text.contains("utilization %"));
}

#[test]
fn unknown_command_fails() {
    let out = repro(&["wat"]);
    assert!(!out.status.success());
}

#[test]
fn exp_table1_runs() {
    let out = repro(&["exp", "table1"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mul8s_1KVP"));
    assert!(text.contains("Table I"));
}

#[test]
fn search_subcommand_runs_budgeted() {
    let out = repro(&[
        "search", "--net", "mlp3", "--strategy", "nsga2", "--budget", "10",
        "--faults", "4", "--images", "8", "--eval-images", "32",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("search frontier"), "{text}");
    assert!(text.contains("hypervolume"), "{text}");
    assert!(text.contains("evaluations:"), "{text}");
}

#[test]
fn search_accepts_fidelity_ladder_knobs() {
    let out = repro(&[
        "search", "--net", "mlp3", "--strategy", "nsga2", "--budget", "10",
        "--faults", "16", "--images", "8", "--eval-images", "32",
        "--fi-screen", "4", "--fi-epsilon", "0.5",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("FI ledger"), "{text}");
    assert!(text.contains("promotions"), "{text}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fi-epsilon 0.5pp"), "{err}");
}

#[test]
fn search_rejects_unknown_strategy() {
    let out = repro(&["search", "--net", "mlp3", "--strategy", "quantum"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown strategy"), "{err}");
}

#[test]
fn zoo_list_runs_without_artifacts() {
    let out = repro_no_artifacts(&["zoo", "list"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["lenet5", "convnet-11", "mlp-deep-16", "zoo-tiny"] {
        assert!(text.contains(name), "missing {name}: {text}");
    }
    assert!(text.contains("grammar"), "{text}");
}

#[test]
fn zoo_build_prints_stable_digest_without_artifacts() {
    let run = || {
        let out = repro_no_artifacts(&[
            "zoo", "build", "--spec", "i1x6x6-C3k3p1-P2-F8-F4", "--seed", "9", "--images", "12",
        ]);
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        let digest_line = text
            .lines()
            .find(|l| l.starts_with("digest "))
            .unwrap_or_else(|| panic!("no digest line in {text}"))
            .to_string();
        (text, digest_line)
    };
    let (text, d1) = run();
    let (_, d2) = run();
    assert_eq!(d1, d2, "zoo build must be deterministic across processes");
    assert!(text.contains("computing layers"), "{text}");
    // an invalid spec fails with the grammar error, not a panic
    let bad = repro_no_artifacts(&["zoo", "build", "--spec", "i1x4x4-Q9"]);
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("token"), "bad-spec diagnostics");
}

#[test]
fn zoo_search_runs_budgeted_without_artifacts() {
    let out = repro_no_artifacts(&[
        "zoo", "search", "--net", "zoo-tiny", "--strategy", "nsga2", "--budget", "6",
        "--faults", "4", "--images", "8", "--eval-images", "16",
        "--fi-screen", "2", "--fi-epsilon", "0.5",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("search frontier"), "{text}");
    assert!(text.contains("hypervolume2d"), "{text}");
    assert!(text.contains("hypervolume3d"), "{text}");
    assert!(text.contains("FI ledger"), "{text}");
}

#[test]
fn zoo_sweep_experiment_runs_deep_net_without_artifacts() {
    // the PR acceptance criterion: `repro exp zoo-sweep` runs a
    // >=12-computing-layer zoo net end to end (NSGA-II + anneal, staged
    // fidelity) and prints a hypervolume2d/3d comparison — no artifacts
    let out = repro_no_artifacts(&[
        "exp", "zoo-sweep", "--budget", "8",
        "--faults", "6", "--images", "8", "--eval-images", "24",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("zoo-sweep"), "{text}");
    assert!(text.contains("16 computing layers"), "{text}");
    assert!(text.contains("nsga2"), "{text}");
    assert!(text.contains("anneal"), "{text}");
    assert!(text.contains("hv2d") && text.contains("hv3d"), "{text}");
    assert!(text.contains("FI ledger"), "{text}");
}

#[test]
fn pipeline_accepts_strategy_flag() {
    let out = repro(&[
        "pipeline", "--net", "mlp3", "--strategy", "anneal", "--budget", "8",
        "--max-acc-drop", "50", "--max-vuln", "100",
        "--faults", "4", "--images", "8", "--eval-images", "32",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pipeline[anneal]"), "{text}");
}
