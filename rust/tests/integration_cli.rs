//! End-to-end CLI tests: run the `repro` binary against the artifacts.

mod common;

use std::process::Command;

fn repro(args: &[&str]) -> std::process::Output {
    common::ensure_artifacts();
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .env("DEEPAXE_ARTIFACTS", common::artifacts())
        .env("DEEPAXE_QUIET", "1")
        .output()
        .expect("spawning repro")
}

#[test]
fn help_prints_usage() {
    let out = repro(&["--help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("pipeline"));
}

#[test]
fn info_lists_model_zoo() {
    let out = repro(&["info"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for net in ["mlp3", "mlp5", "mlp7", "lenet5", "alexnet"] {
        assert!(text.contains(net), "missing {net}: {text}");
    }
}

#[test]
fn faults_prints_leveugle_sizing() {
    let out = repro(&["faults"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Leveugle"));
    assert!(text.contains("mlp3"));
}

#[test]
fn eval_single_config() {
    let out = repro(&[
        "eval", "--net", "mlp3", "--mult", "kvp", "--config", "101",
        "--fi", "--faults", "6", "--images", "12", "--eval-images", "40",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("acc drop pp"));
    assert!(text.contains("utilization %"));
}

#[test]
fn unknown_command_fails() {
    let out = repro(&["wat"]);
    assert!(!out.status.success());
}

#[test]
fn exp_table1_runs() {
    let out = repro(&["exp", "table1"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mul8s_1KVP"));
    assert!(text.contains("Table I"));
}

#[test]
fn search_subcommand_runs_budgeted() {
    let out = repro(&[
        "search", "--net", "mlp3", "--strategy", "nsga2", "--budget", "10",
        "--faults", "4", "--images", "8", "--eval-images", "32",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("search frontier"), "{text}");
    assert!(text.contains("hypervolume"), "{text}");
    assert!(text.contains("evaluations:"), "{text}");
}

#[test]
fn search_accepts_fidelity_ladder_knobs() {
    let out = repro(&[
        "search", "--net", "mlp3", "--strategy", "nsga2", "--budget", "10",
        "--faults", "16", "--images", "8", "--eval-images", "32",
        "--fi-screen", "4", "--fi-epsilon", "0.5",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("FI ledger"), "{text}");
    assert!(text.contains("promotions"), "{text}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fi-epsilon 0.5pp"), "{err}");
}

#[test]
fn search_rejects_unknown_strategy() {
    let out = repro(&["search", "--net", "mlp3", "--strategy", "quantum"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown strategy"), "{err}");
}

#[test]
fn pipeline_accepts_strategy_flag() {
    let out = repro(&[
        "pipeline", "--net", "mlp3", "--strategy", "anneal", "--budget", "8",
        "--max-acc-drop", "50", "--max-vuln", "100",
        "--faults", "4", "--images", "8", "--eval-images", "32",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("pipeline[anneal]"), "{text}");
}
