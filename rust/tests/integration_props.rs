//! Cross-cutting property tests on the real artifacts: invariants the
//! framework's conclusions depend on (quantization monotonicity, engine
//! linear-algebra ground truth, campaign clamping, HLS model composition).

mod common;

use deepaxe::simnet::layers::requantize;
use deepaxe::simnet::{Buffers, CompKind, Engine, Layer};
use deepaxe::util::proptest::check;
use deepaxe::util::rng::Rng;

#[test]
fn requantize_monotone_in_accumulator() {
    check("requantize monotone", 0x9001, 60, |rng| {
        let m0 = (1i64 << 30) + rng.below(1 << 30) as i64;
        let nshift = 31 + rng.below(20) as u32;
        let a = rng.next_u64() as i32 / 2;
        let b = rng.next_u64() as i32 / 2;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (ylo, yhi) = (requantize(lo, m0, nshift, false), requantize(hi, m0, nshift, false));
        assert!(ylo <= yhi, "requant not monotone: {lo}->{ylo} vs {hi}->{yhi}");
    });
}

/// Ground truth: with the exact LUT, a dense layer must equal an i64
/// matmul computed by a totally independent implementation.
#[test]
fn exact_engine_equals_integer_matmul() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let mut buf = Buffers::for_net(&net);

    // independent scalar forward in i64
    let img = data.image(0);
    let mut act: Vec<i64> = img.iter().map(|&v| v as i64).collect();
    for ci in 0..net.n_comp() {
        let c = net.comp(ci);
        assert!(matches!(c.kind, CompKind::Dense));
        let mut next = vec![0i64; c.n_dim];
        for (j, nj) in next.iter_mut().enumerate() {
            let mut acc = c.b[j] as i64;
            for (k, &a) in act.iter().enumerate() {
                acc += a * c.w[k * c.n_dim + j] as i64;
            }
            // requant
            let y = ((acc * c.m0) + (1i64 << (c.nshift - 1))) >> c.nshift;
            let mut y = y.clamp(-128, 127);
            if c.relu && y < 0 {
                y = 0;
            }
            *nj = y;
        }
        act = next;
    }
    let expect: Vec<i8> = act.iter().map(|&v| v as i8).collect();
    let got = engine.forward(img, None, &mut buf);
    assert_eq!(got, expect);
}

#[test]
fn campaign_clamps_oversized_subset() {
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let params = deepaxe::faultsim::CampaignParams {
        n_faults: 4,
        n_images: 10_000_000, // way beyond the test set
        seed: 1,
        workers: 1,
        sampling: deepaxe::faultsim::SiteSampling::UniformLayer,
        replay: true,
        gate: true,
        delta: true,
        batch: true,
    };
    let r = deepaxe::faultsim::run_campaign(&engine, &data, &params);
    assert_eq!(r.n_images, data.len());
}

#[test]
fn hwmodel_per_layer_sums_to_totals() {
    let ctx = common::ctx();
    for name in ["mlp3", "lenet5", "alexnet"] {
        let net = ctx.net(name).unwrap();
        let mults: Vec<_> =
            (0..net.n_comp()).map(|_| deepaxe::axmul::by_name("exact").unwrap()).collect();
        let r = deepaxe::hwmodel::estimate(&net, &mults);
        let layer_luts: u64 = r.per_layer.iter().map(|l| l.luts).sum();
        let layer_ffs: u64 = r.per_layer.iter().map(|l| l.ffs).sum();
        let layer_cycles: u64 = r.per_layer.iter().map(|l| l.cycles).sum();
        assert!(layer_luts < r.luts, "{name}: base overhead must be positive");
        assert!(layer_ffs < r.ffs);
        assert!(layer_cycles <= r.cycles, "{name}: pool/io cycles must be non-negative");
        assert_eq!(r.per_layer.len(), net.n_comp());
        let macs: u64 = r.per_layer.iter().map(|l| l.macs).sum();
        assert_eq!(macs, net.total_macs());
    }
}

#[test]
fn config_string_roundtrips_masks() {
    let ctx = common::ctx();
    check("config_string <-> mask", 0xC0F1, 50, |rng| {
        for name in ["mlp3", "lenet5", "alexnet"] {
            let net = ctx.net(name).unwrap();
            let mask = rng.below(1 << net.n_comp());
            let s = net.config_string(mask);
            let back = deepaxe::dse::mask_from_config_string(&s).unwrap();
            assert_eq!(back, mask, "{name} {s}");
        }
    });
}

#[test]
fn property_convergence_gated_replay_matches_full_forward() {
    // for random sites on a real net, the gated replay's prediction must
    // equal the naive faulted forward's, and a convergence exit must
    // imply the clean prediction
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap().take(8);
    let engine = Engine::uniform(&net, &ctx.luts["mul8s_1kvp_s"]);
    let mut buf = Buffers::for_net(&net);
    check("gated replay == full forward", 0x6A7E, 40, |rng| {
        let i = rng.usize_below(data.len());
        let tr = engine.trace(data.image(i), &mut buf);
        let layer = rng.usize_below(net.n_comp());
        let neuron = rng.usize_below(net.comp(layer).act_len());
        let bit = rng.below(8) as u8;
        let mut act = tr.acts[layer].clone();
        act[neuron] = (act[neuron] as u8 ^ (1 << bit)) as i8;
        let gated = engine.replay_from(layer, &act, &tr, true, &mut buf);
        let ungated = engine.replay_from(layer, &act, &tr, false, &mut buf);
        let full = engine.forward(
            data.image(i),
            Some(deepaxe::simnet::FaultSite { layer, neuron, bit }),
            &mut buf,
        );
        assert_eq!(gated.pred, deepaxe::simnet::argmax_i8(&full));
        assert_eq!(gated.pred, ungated.pred);
        assert_eq!(ungated.depth, net.n_comp() - 1 - layer);
        if gated.converged {
            assert_eq!(gated.pred, tr.pred, "convergence implies the clean prediction");
        }
    });
}

#[test]
fn fault_free_mask_zero_fault_identity() {
    // A fault with bit value XOR 0 semantics: flipping the same bit twice
    // restores the baseline prediction for every image.
    let ctx = common::ctx();
    let net = ctx.net("mlp3").unwrap();
    let data = ctx.data_for(&net).unwrap().take(16);
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let mut buf = Buffers::for_net(&net);
    let mut rng = Rng::new(0xF00D);
    for i in 0..data.len() {
        let tr = engine.trace(data.image(i), &mut buf);
        let layer = rng.usize_below(net.n_comp());
        let neuron = rng.usize_below(net.comp(layer).act_len());
        let bit = rng.below(8) as u8;
        let mut act = tr.acts[layer].clone();
        act[neuron] = (act[neuron] as u8 ^ (1 << bit)) as i8;
        act[neuron] = (act[neuron] as u8 ^ (1 << bit)) as i8; // undo
        let replay = engine.forward_from(layer, &act, &mut buf);
        assert_eq!(replay, tr.logits);
    }
}

#[test]
fn more_approximation_never_costs_more_hardware() {
    // monotonicity of the HLS model in the layer mask (per multiplier)
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    check("hw cost monotone in mask", 0xAB, 40, |rng| {
        let m = deepaxe::axmul::by_name("mul8s_1kvp_s").unwrap();
        let exact = deepaxe::axmul::by_name("exact").unwrap();
        let mask = rng.below(1 << net.n_comp());
        let sub = mask & rng.next_u64(); // subset of mask
        let cfg = |mk: u64| -> Vec<&deepaxe::axmul::Multiplier> {
            (0..net.n_comp()).map(|ci| if mk >> ci & 1 == 1 { m } else { exact }).collect()
        };
        let full = deepaxe::hwmodel::estimate(&net, &cfg(mask));
        let less = deepaxe::hwmodel::estimate(&net, &cfg(sub));
        assert!(full.luts <= less.luts);
        assert!(full.cycles <= less.cycles);
        assert!(full.util_pct <= less.util_pct + 1e-12);
    });
}
