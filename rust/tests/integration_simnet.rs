//! simnet vs the python-pinned artifacts: the rust engine must reproduce
//! the JAX reference predictions bit-for-bit (exact LUT, approximate LUT,
//! and injected-fault cases).

mod common;

use deepaxe::axmul;
use deepaxe::nbin::Nbin;
use deepaxe::simnet::{Buffers, Engine, FaultSite};

const NETS: &[&str] = &["mlp3", "mlp5", "mlp7", "lenet5", "alexnet"];

fn expected(net: &str) -> Nbin {
    Nbin::read_file(common::artifacts().join(format!("{net}.expected.nbin"))).unwrap()
}

#[test]
fn predictions_match_python_exact_lut() {
    let ctx = common::ctx();
    for net_name in NETS {
        let net = ctx.net(net_name).unwrap();
        let data = ctx.data_for(&net).unwrap();
        let exp = expected(net_name);
        let pred_exact = exp.get_i32("pred_exact").unwrap();
        let engine = Engine::uniform(&net, &ctx.luts["exact"]);
        let mut buf = Buffers::for_net(&net);
        for (i, &want) in pred_exact.iter().enumerate() {
            let got = engine.predict(data.image(i), None, &mut buf);
            assert_eq!(got as i32, want, "{net_name} image {i}");
        }
    }
}

#[test]
fn predictions_match_python_kvp_lut() {
    let ctx = common::ctx();
    for net_name in NETS {
        let net = ctx.net(net_name).unwrap();
        let data = ctx.data_for(&net).unwrap();
        let exp = expected(net_name);
        let pred_axm = exp.get_i32("pred_axm_kvp").unwrap();
        let engine = Engine::uniform(&net, &ctx.luts["mul8s_1kvp_s"]);
        let mut buf = Buffers::for_net(&net);
        for (i, &want) in pred_axm.iter().enumerate() {
            let got = engine.predict(data.image(i), None, &mut buf);
            assert_eq!(got as i32, want, "{net_name} image {i}");
        }
    }
}

#[test]
fn fault_injection_matches_python() {
    let ctx = common::ctx();
    for net_name in NETS {
        let net = ctx.net(net_name).unwrap();
        let data = ctx.data_for(&net).unwrap();
        let exp = expected(net_name);
        let sites = exp.get_i32("fault_sites").unwrap(); // [F, 3]
        let preds = exp.get_i32("pred_fault").unwrap(); // [F, n_img]
        let n_cases = exp.get("fault_sites").unwrap().dims[0];
        let n_img = exp.get("pred_fault").unwrap().dims[1];
        let engine = Engine::uniform(&net, &ctx.luts["exact"]);
        let mut buf = Buffers::for_net(&net);
        for f in 0..n_cases {
            let site = FaultSite {
                layer: sites[f * 3] as usize,
                neuron: sites[f * 3 + 1] as usize,
                bit: sites[f * 3 + 2] as u8,
            };
            for i in 0..n_img {
                let got = engine.predict(data.image(i), Some(site), &mut buf);
                assert_eq!(
                    got as i32,
                    preds[f * n_img + i],
                    "{net_name} fault {site:?} image {i}"
                );
            }
        }
    }
}

#[test]
fn rust_luts_match_artifact_luts() {
    // The rust axmul generators must be bit-identical to the python-written
    // artifacts (cross-language drift guard).
    common::ensure_artifacts();
    for m in axmul::CATALOG {
        let path = common::artifacts().join("luts").join(format!("{}.nbin", m.name));
        let artifact = axmul::Lut::load(&path).unwrap();
        let generated = m.lut();
        assert_eq!(artifact.table, generated.table, "{}", m.name);
    }
}

#[test]
fn engine_accuracy_close_to_build_accuracy() {
    // subset accuracy should be within a few points of the python-reported
    // full-test accuracy
    let ctx = common::ctx();
    for net_name in NETS {
        let net = ctx.net(net_name).unwrap();
        let data = ctx.data_for(&net).unwrap();
        let engine = Engine::uniform(&net, &ctx.luts["exact"]);
        let mut buf = Buffers::for_net(&net);
        let acc = engine.accuracy(&data.take(200), &mut buf);
        let build = ctx.build_quant_acc(net_name).unwrap();
        assert!(
            (acc - build).abs() < 0.08,
            "{net_name}: subset acc {acc} vs build {build}"
        );
    }
}

#[test]
fn layer_replay_equivalence_on_real_net() {
    let ctx = common::ctx();
    let net = ctx.net("lenet5").unwrap();
    let data = ctx.data_for(&net).unwrap();
    let engine = Engine::uniform(&net, &ctx.luts["mul8s_1kv9_s"]);
    let mut buf = Buffers::for_net(&net);
    let img = data.image(3);
    let trace = engine.trace(img, &mut buf);
    for (layer, neuron, bit) in [(0usize, 100usize, 7u8), (1, 50, 3), (2, 10, 0), (4, 5, 6)] {
        let site = FaultSite { layer, neuron, bit };
        let full = engine.forward(img, Some(site), &mut buf);
        let mut act = trace.acts[layer].clone();
        act[neuron] = (act[neuron] as u8 ^ (1 << bit)) as i8;
        let replay = engine.forward_from(layer, &act, &mut buf);
        assert_eq!(full, replay, "site {site:?}");
    }
}
