//! Budgeted multi-objective search on LeNet-5: NSGA-II over the
//! generalized per-layer multiplier assignment space (4^5 = 1024 configs)
//! with a budget of 24 evaluations — ~25% of the paper's exhaustive
//! 94-point grid — then the exhaustive grid for comparison.
//!
//! Run: `cargo run --release --example search_lenet`
//! (env knobs: DEEPAXE_FI_FAULTS / DEEPAXE_FI_IMAGES / DEEPAXE_EVAL_IMAGES)

use anyhow::Result;
use deepaxe::coordinator::jobs::{run_sweep, SweepSpec};
use deepaxe::coordinator::Ctx;
use deepaxe::dse::cache::ResultCache;
use deepaxe::dse::{enumerate_masks, Evaluator};
use deepaxe::faultsim::CampaignParams;
use deepaxe::report::experiments::default_eval_images;
use deepaxe::search::{
    frontier_hv, run_search, EvaluatorBackend, ResultCacheHook, SearchSpace, SearchSpec, Strategy,
};

fn main() -> Result<()> {
    let ctx = Ctx::load()?;
    let net = ctx.net("lenet5")?;
    let data = ctx.data_for(&net)?;
    let fi = CampaignParams::default_for(&net.name);
    let ev = Evaluator::new(&net, &data, &ctx.luts, default_eval_images(), fi.clone());
    let mut cache = ResultCache::open(ctx.results.join("results.jsonl"));

    let mults: Vec<String> = deepaxe::axmul::PAPER_AXMS.iter().map(|m| m.to_string()).collect();
    let space = SearchSpace::paper(&net, &mults);
    println!(
        "space: {} layers x alphabet [{}] = {} configurations",
        space.n_layers,
        space.alphabet.join(","),
        space.size()
    );

    // -- budgeted NSGA-II ---------------------------------------------------
    let mut spec = SearchSpec::new(Strategy::Nsga2);
    spec.budget = 24;
    spec.seed = fi.seed;
    let backend = EvaluatorBackend { ev: &ev };
    let mut hook = ResultCacheHook {
        cache: &mut cache,
        net: net.name.clone(),
        fi: fi.clone(),
        eval_images: default_eval_images(),
    };
    let out = run_search(&space, &spec, &backend, &mut hook);
    println!(
        "\nNSGA-II: {} evaluations ({} cache hits), frontier {} points, hypervolume {:.1}",
        out.evals_used,
        out.cache_hits,
        out.frontier_idx.len(),
        out.hypervolume()
    );
    for p in out.frontier() {
        println!(
            "  {}  acc drop {:>6.2}pp  FI drop {:>6.2}pp  util {:>5.2}%",
            p.config_string, p.acc_drop_pct, p.fault_vuln_pct, p.util_pct
        );
    }

    // -- exhaustive reference (the paper's Fig. 3 grid) ---------------------
    let ex_spec = SweepSpec {
        mults: deepaxe::axmul::PAPER_AXMS.to_vec(),
        masks: enumerate_masks(net.n_comp()),
        with_fi: true,
    };
    let ex_evals = ex_spec.n_points();
    let ex_points = run_sweep(&ev, &mut cache, &ex_spec)?;
    let (ex_front, ex_hv) = frontier_hv(&ex_points, true);
    println!(
        "\nexhaustive: {} evaluations, frontier {} points, hypervolume {:.1}",
        ex_evals,
        ex_front.len(),
        ex_hv
    );
    println!(
        "search reached {:.1}% of the exhaustive hypervolume with {:.0}% of its evaluations",
        out.hypervolume() / ex_hv.max(1e-12) * 100.0,
        out.evals_used as f64 / ex_evals as f64 * 100.0
    );
    Ok(())
}
