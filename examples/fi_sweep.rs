//! Fault-injection deep dive: per-layer and per-bit vulnerability profile
//! of a network (the kind of tailored analysis the paper motivates in
//! §IV-C — "several configurations do not follow this trend and a
//! tailored analysis ... is necessary").
//!
//! Run: `cargo run --release --example fi_sweep -- [net]` (default mlp3)

use anyhow::Result;
use deepaxe::coordinator::Ctx;
use deepaxe::report::table::{f2, Table};
use deepaxe::simnet::{argmax_i8, Buffers, Engine};
use deepaxe::util::cli::env_usize;

fn main() -> Result<()> {
    let net_name = std::env::args().nth(1).unwrap_or_else(|| "mlp3".into());
    let ctx = Ctx::load()?;
    let net = ctx.net(&net_name)?;
    let data = ctx.data_for(&net)?.take(env_usize("DEEPAXE_FI_IMAGES", 80));
    let engine = Engine::uniform(&net, &ctx.luts["exact"]);
    let mut buf = Buffers::for_net(&net);

    // clean traces once per image (layer-replay)
    let traces: Vec<_> = (0..data.len()).map(|i| engine.trace(data.image(i), &mut buf)).collect();
    let base_acc = traces
        .iter()
        .zip(&data.labels)
        .filter(|(t, l)| t.pred == **l as usize)
        .count() as f64
        / data.len() as f64;
    println!("{net_name}: base accuracy {:.2}% on {} images", base_acc * 100.0, data.len());

    // per-layer x per-bit exhaustive-ish sweep (sampled neurons per layer)
    let neurons_per_layer = env_usize("DEEPAXE_FI_NEURONS", 24);
    let mut t = Table::new(
        &format!("{net_name}: mean accuracy drop (pp) by fault layer and bit position"),
        &["layer", "neurons", "bit0", "bit2", "bit4", "bit6", "bit7(sign)"],
    );
    let mut rng = deepaxe::util::rng::Rng::new(0xF1);
    for layer in 0..net.n_comp() {
        let act_len = net.comp(layer).act_len();
        let picks = rng.sample_indices(act_len, neurons_per_layer.min(act_len));
        let mut cells = vec![layer.to_string(), act_len.to_string()];
        for bit in [0u8, 2, 4, 6, 7] {
            let mut acc_sum = 0.0;
            for &neuron in &picks {
                let mut correct = 0usize;
                let mut act = Vec::new();
                for (i, tr) in traces.iter().enumerate() {
                    act.clear();
                    act.extend_from_slice(&tr.acts[layer]);
                    act[neuron] = (act[neuron] as u8 ^ (1 << bit)) as i8;
                    let pred = argmax_i8(&engine.forward_from(layer, &act, &mut buf));
                    if pred == data.labels[i] as usize {
                        correct += 1;
                    }
                }
                acc_sum += correct as f64 / data.len() as f64;
            }
            let drop_pp = (base_acc - acc_sum / picks.len() as f64) * 100.0;
            cells.push(f2(drop_pp));
        }
        t.row(cells);
    }
    print!("{}", t.render());
    println!("(higher = more vulnerable; sign/high bits should dominate, early layers amplify)");
    Ok(())
}
