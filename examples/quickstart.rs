//! Quickstart — the end-to-end validation driver (DESIGN.md E7).
//!
//! Exercises every layer of the stack on a real workload:
//!   1. loads the LeNet-5 artifacts (trained + quantized by `make
//!      artifacts`, never retrained here),
//!   2. runs the automated DeepAxe pipeline (accuracy sweep -> fault
//!      injection -> HLS estimation -> Pareto selection) under a
//!      reliability/accuracy requirement,
//!   3. deploys the selected approximate configuration on the AOT-lowered
//!      PJRT executable (the L1 Pallas kernel inside the L2 JAX graph,
//!      executed from rust), and
//!   4. cross-checks PJRT vs the native simnet engine and reports the
//!      headline metrics.
//!
//! Run: `cargo run --release --example quickstart`
//! (scale with DEEPAXE_FI_FAULTS / DEEPAXE_FI_IMAGES / DEEPAXE_EVAL_IMAGES)

use anyhow::{Context, Result};
use deepaxe::coordinator::pipeline::{run_pipeline, PipelineSpec};
use deepaxe::coordinator::Ctx;
use deepaxe::faultsim::CampaignParams;
use deepaxe::simnet::{Buffers, Engine};
use std::time::Instant;

fn main() -> Result<()> {
    let t0 = Instant::now();
    let ctx = Ctx::load()?;
    let net = ctx.net("lenet5")?;
    let data = ctx.data_for(&net)?;
    println!(
        "loaded lenet5: {} computing layers, {} MACs/inference, build quant acc {:.2}%",
        net.n_comp(),
        net.total_macs(),
        ctx.build_quant_acc("lenet5").unwrap_or(f64::NAN) * 100.0
    );

    // ---- 2) automated design pipeline ------------------------------------
    let spec = PipelineSpec {
        net: "lenet5".into(),
        mults: vec!["mul8s_1kvp_s".into(), "mul8s_1kv9_s".into(), "mul8s_1kv8_s".into()],
        max_acc_drop_pct: 2.0,
        max_vuln_pct: 25.0,
        eval_images: deepaxe::report::experiments::default_eval_images(),
        fi: CampaignParams::default_for("lenet5"),
        strategy: deepaxe::search::Strategy::Exhaustive,
        budget: 0,
        fi_epsilon: 0.0,
        fi_screen: 0,
        fi_screen_auto: false,
    };
    println!(
        "\nrunning DeepAxe pipeline (max acc drop {:.1}pp, max vulnerability {:.1}pp)...",
        spec.max_acc_drop_pct, spec.max_vuln_pct
    );
    let out = run_pipeline(&ctx, &spec)?;
    println!(
        "pipeline: {} configurations accuracy-checked, {} fault-simulated, {} feasible",
        out.accuracy_sweep.len(),
        out.fi_points.len(),
        out.feasible.len()
    );
    let sel = out.selected.context("no feasible design under the requirements")?;
    println!(
        "selected design: {} {} | acc drop {:.2}pp | vulnerability {:.2}pp | {} cycles | util {:.2}%",
        sel.mult, sel.config_string, sel.acc_drop_pct, sel.fault_vuln_pct, sel.cycles, sel.util_pct
    );

    // ---- 3) deploy on the AOT PJRT executable -----------------------------
    let rt = deepaxe::runtime::Runtime::cpu()?;
    let exe = rt.load_net(&ctx.artifacts, &net, ctx.lower_batch())?;
    let exact = &ctx.luts["exact"];
    let axm = &ctx.luts[&sel.mult];
    let luts: Vec<&deepaxe::axmul::Lut> = (0..net.n_comp())
        .map(|ci| if sel.mask >> ci & 1 == 1 { axm } else { exact })
        .collect();
    let n_eval = 128.min(data.len());
    let t_inf = Instant::now();
    let preds = exe.predict_all(&data.take(n_eval), &luts, None)?;
    let pjrt_s = t_inf.elapsed().as_secs_f64();
    let correct = preds
        .iter()
        .zip(&data.labels)
        .filter(|(p, l)| **p == **l as usize)
        .count();
    println!(
        "\nPJRT deployment: {}/{} correct ({:.2}%) over {} images, {:.2} ms/inference",
        correct,
        n_eval,
        correct as f64 / n_eval as f64 * 100.0,
        n_eval,
        pjrt_s / n_eval as f64 * 1e3
    );

    // ---- 4) parity: PJRT executable vs native engine ----------------------
    let engine = Engine::new(&net, luts.clone());
    let mut buf = Buffers::for_net(&net);
    let mut mismatch = 0;
    for i in 0..n_eval {
        if engine.predict(data.image(i), None, &mut buf) != preds[i] {
            mismatch += 1;
        }
    }
    println!("parity simnet vs PJRT: {mismatch}/{n_eval} mismatches");
    anyhow::ensure!(mismatch == 0, "engines disagree");

    println!(
        "\nquickstart complete in {:.1}s — estimated FPGA deployment: {} cycles @100MHz = {:.2} ms/inference, {:.2}% of xc7s100",
        t0.elapsed().as_secs_f64(),
        sel.cycles,
        sel.cycles as f64 / 100e6 * 1e3,
        sel.util_pct
    );
    Ok(())
}
