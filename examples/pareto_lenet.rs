//! Fig. 3 as a runnable example: full LeNet-5 design-space sweep (2^5 layer
//! masks x 3 approximate multipliers, fault-simulated) and the Pareto
//! frontier over (resource utilization, FI accuracy drop), rendered as an
//! ASCII scatter like the paper's chart.
//!
//! Run: `cargo run --release --example pareto_lenet`

use anyhow::Result;
use deepaxe::coordinator::Ctx;
use deepaxe::report::experiments::fig3;

fn ascii_scatter(points: &[(f64, f64, bool)], w: usize, h: usize) -> String {
    // x = utilization, y = FI acc drop; frontier points drawn as '#'
    let (xmin, xmax) = points.iter().fold((f64::MAX, f64::MIN), |(a, b), p| (a.min(p.0), b.max(p.0)));
    let (ymin, ymax) = points.iter().fold((f64::MAX, f64::MIN), |(a, b), p| (a.min(p.1), b.max(p.1)));
    let mut grid = vec![vec![' '; w]; h];
    for &(x, y, front) in points {
        let xi = (((x - xmin) / (xmax - xmin + 1e-12)) * (w - 1) as f64) as usize;
        let yi = (((y - ymin) / (ymax - ymin + 1e-12)) * (h - 1) as f64) as usize;
        let row = h - 1 - yi;
        let c = if front { '#' } else { '.' };
        if grid[row][xi] != '#' {
            grid[row][xi] = c;
        }
    }
    let mut out = format!("FI acc drop {ymax:.1}pp\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out += &format!("{ymin:.1}pp +{}\n", "-".repeat(w));
    out += &format!("      util {xmin:.2}% .. {xmax:.2}%   ('#' = Pareto frontier)\n");
    out
}

fn main() -> Result<()> {
    let ctx = Ctx::load()?;
    let report = fig3(&ctx)?;
    println!("{report}");

    // re-read the CSV this run just wrote and draw the scatter
    let csv = std::fs::read_to_string(ctx.results.join("fig3a_points.csv"))?;
    let frontier_csv = std::fs::read_to_string(ctx.results.join("fig3b_frontier.csv"))?;
    let frontier_keys: std::collections::HashSet<String> = frontier_csv
        .lines()
        .skip(1)
        .map(|l| {
            let cells: Vec<&str> = l.split(',').collect();
            cells[2].trim_matches('"').to_string() // "AxM config"
        })
        .collect();
    let mut pts = Vec::new();
    for line in csv.lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        let key = format!("{} {}", cells[0], cells[1]);
        let util: f64 = cells[2].parse().unwrap_or(f64::NAN);
        let drop: f64 = cells[3].parse().unwrap_or(f64::NAN);
        if util.is_finite() && drop.is_finite() {
            pts.push((util, drop, frontier_keys.contains(&key)));
        }
    }
    println!("{}", ascii_scatter(&pts, 72, 20));
    println!("full data: results/fig3a_points.csv, frontier: results/fig3b_frontier.csv");
    Ok(())
}
