//! The paper's §IV-D case study as a runnable example: full approximation
//! of the 3/5/7-layer MLPs under each approximate multiplier, reporting
//! accuracy drop, fault vulnerability and normalized latency/resources —
//! the "which AxM should I pick for this network?" guide (Table IV).
//!
//! Run: `cargo run --release --example axmul_casestudy`

use anyhow::Result;
use deepaxe::coordinator::Ctx;
use deepaxe::report::experiments::table4;

fn main() -> Result<()> {
    let ctx = Ctx::load()?;
    println!("{}", table4(&ctx)?);
    println!(
        "reading the table (paper §IV-D): for the deeper MLPs a mild AxM\n\
         (1KV8/1KV9) keeps accuracy while the aggressive 1KVP buys ~25%\n\
         latency and ~24% resources — but for the shallow MLP-3 the same\n\
         1KVP costs several accuracy points: per-network AxM exploration\n\
         (what DeepAxe automates) is necessary."
    );
    Ok(())
}
