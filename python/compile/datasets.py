"""Deterministic synthetic datasets (MNIST / CIFAR-10 stand-ins).

The offline image cannot download MNIST or CIFAR-10; DESIGN.md §2 documents
the substitution. Both generators are pure-numpy, seeded, and preserve the
properties the paper's experiments rely on: 10 classes, the same input
shapes (28×28×1 and 32×32×3), intra-class variability large enough that
(a) the three-network difficulty ordering holds and (b) a single-bit
activation fault can move predictions.

* synmnist — digit glyphs from a built-in 7×5 bitmap font, placed with a
  random affine jitter (shift / scale / rotation), stroke-thickness
  variation and additive noise, rendered at 28×28 grayscale.
* syncifar — 10 parametric shape/texture classes (stripes, checker, disk,
  ring, square, cross, diagonal gradient, blobs, triangle, noise-walk)
  with randomized colors, geometry and noise at 32×32 RGB.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

# 7x5 digit glyphs (classic LCD-style font), rows top->bottom.
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph(digit: int) -> np.ndarray:
    rows = _FONT[digit]
    return np.array([[int(c) for c in r] for r in rows], dtype=np.float32)


def _render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one jittered digit on a 28x28 canvas, values in [0, 1]."""
    g = _glyph(digit)  # 7x5
    # Upsample to a base stamp with random stroke thickness. Jitter ranges
    # are tuned so the task is non-trivial (quantized accuracies land in the
    # 80-95% band like the paper's baselines, leaving dynamic range for the
    # approximation / fault-injection accuracy drops).
    scale_y = rng.uniform(1.6, 3.4)
    scale_x = rng.uniform(1.6, 3.4)
    angle = rng.uniform(-0.55, 0.55)  # radians, ~±32 degrees
    cx = 14.0 + rng.uniform(-3.5, 3.5)
    cy = 14.0 + rng.uniform(-3.5, 3.5)
    # Inverse-map each canvas pixel into glyph space (bilinear sample).
    ys, xs = np.mgrid[0:28, 0:28].astype(np.float32)
    ca, sa = np.cos(angle), np.sin(angle)
    u = (ca * (xs - cx) + sa * (ys - cy)) / scale_x + 2.5
    v = (-sa * (xs - cx) + ca * (ys - cy)) / scale_y + 3.5
    u0 = np.floor(u).astype(np.int32)
    v0 = np.floor(v).astype(np.int32)
    fu, fv = u - u0, v - v0

    def sample(vv: np.ndarray, uu: np.ndarray) -> np.ndarray:
        ok = (uu >= 0) & (uu < 5) & (vv >= 0) & (vv < 7)
        out = np.zeros_like(fu)
        out[ok] = g[vv[ok], uu[ok]]
        return out

    img = (
        sample(v0, u0) * (1 - fu) * (1 - fv)
        + sample(v0, u0 + 1) * fu * (1 - fv)
        + sample(v0 + 1, u0) * (1 - fu) * fv
        + sample(v0 + 1, u0 + 1) * fu * fv
    )
    # Stroke intensity variation + background noise.
    img = np.clip(img * rng.uniform(0.5, 1.0), 0.0, 1.0)
    img += rng.normal(0.0, 0.18, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def synmnist(n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """n images [n,1,28,28] float32 in [0,1] and labels [n] int32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render_digit(int(d), rng) for d in labels])
    return imgs[:, None, :, :], labels


# ---------------------------------------------------------------------------
# syncifar
# ---------------------------------------------------------------------------


def _coords() -> Tuple[np.ndarray, np.ndarray]:
    ys, xs = np.mgrid[0:32, 0:32].astype(np.float32)
    return ys, xs


def _render_cifar(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One 32x32 RGB image in [0,1] for class `cls`."""
    ys, xs = _coords()
    # Overlapping fg/bg ranges + heavier noise keep the task non-trivial.
    fg = rng.uniform(0.3, 0.85, size=3).astype(np.float32)
    bg = rng.uniform(0.15, 0.6, size=3).astype(np.float32)
    cx, cy = rng.uniform(10, 22), rng.uniform(10, 22)
    r = rng.uniform(6, 12)
    period = rng.uniform(4.0, 8.0)
    phase = rng.uniform(0, period)
    if cls == 0:  # horizontal stripes
        m = ((ys + phase) % period) < period / 2
    elif cls == 1:  # vertical stripes
        m = ((xs + phase) % period) < period / 2
    elif cls == 2:  # filled disk
        m = (xs - cx) ** 2 + (ys - cy) ** 2 < r**2
    elif cls == 3:  # ring
        d2 = (xs - cx) ** 2 + (ys - cy) ** 2
        m = (d2 < r**2) & (d2 > (r * 0.55) ** 2)
    elif cls == 4:  # checkerboard
        m = (((xs + phase) // (period / 2)).astype(int) + ((ys + phase) // (period / 2)).astype(int)) % 2 == 0
    elif cls == 5:  # axis-aligned square
        half = r * 0.8
        m = (np.abs(xs - cx) < half) & (np.abs(ys - cy) < half)
    elif cls == 6:  # cross
        w = rng.uniform(2.0, 4.0)
        m = (np.abs(xs - cx) < w) | (np.abs(ys - cy) < w)
    elif cls == 7:  # diagonal gradient thresholded into two bands
        ang = rng.uniform(0, np.pi)
        proj = xs * np.cos(ang) + ys * np.sin(ang)
        m = ((proj + phase) % (2 * period)) < period
    elif cls == 8:  # triangle (upper half-plane cut by two lines)
        m = (ys > cy - r) & (ys - (cy - r) > np.abs(xs - cx) * 1.6)
    else:  # 9: gaussian blobs
        m = np.zeros_like(xs, dtype=bool)
        for _ in range(3):
            bx, by = rng.uniform(4, 28), rng.uniform(4, 28)
            br = rng.uniform(2.5, 5.0)
            m |= (xs - bx) ** 2 + (ys - by) ** 2 < br**2
    img = np.where(m[None, :, :], fg[:, None, None], bg[:, None, None])
    img = img + rng.normal(0, 0.18, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def syncifar(n: int, seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """n images [n,3,32,32] float32 in [0,1] and labels [n] int32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render_cifar(int(c), rng) for c in labels])
    return imgs, labels


DATASETS = {
    "synmnist": {"gen": synmnist, "shape": (1, 28, 28), "train_seed": 1001, "test_seed": 2002},
    "syncifar": {"gen": syncifar, "shape": (3, 32, 32), "train_seed": 3003, "test_seed": 4004},
}


def load(name: str, split: str, n: int) -> Tuple[np.ndarray, np.ndarray]:
    spec = DATASETS[name]
    seed = spec["train_seed"] if split == "train" else spec["test_seed"]
    return spec["gen"](n, seed)
