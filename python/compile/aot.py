"""AOT build driver: train -> quantize -> evaluate -> lower -> dump.

Runs ONCE per `make artifacts` (the Makefile stamps it); the rust binary is
self-contained afterwards. Emits into artifacts/:

  multipliers.json          catalog + measured Table-I metrics + paper rows
  luts/<name>.nbin          i32[65536] LUT per multiplier
  <dataset>.test.nbin       x_q int8 [N,C,H,W], labels i32 [N]
  <net>.meta.json           topology + quantization parameters
  <net>.weights.nbin        int8 weights / int32 biases (GEMM layout)
  <net>.expected.nbin       pinned predictions for rust parity tests
  <net>.hlo.txt             the L2+L1 graph as HLO text (PJRT interchange)
  manifest.json             accuracies, shapes, build parameters
  .train_cache/             float params cache (skip retraining)

HLO text (NOT lowered.compiler_ir(...).serialize()): the image's
xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction ids;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from . import datasets, luts, nbin, train
from .model import accuracy_int, build_lowerable, predict_int
from .networks import ARCHS
from .quantize import qnet_meta, qnet_tensors, quantize_images, quantize_net

TEST_N = 1000
CALIB_N = 512
LOWER_BATCH = 16
EXPECTED_N = 64  # images pinned for rust parity tests
FAULT_SAMPLES = 6  # pinned fault-injection parity cases per net
# Fixed input scale: synthetic images live in [0, 1], so s_in = 1/127 makes
# the quantized test set shareable across every net on the dataset.
INPUT_SCALE = 1.0 / 127.0

NETS = ["mlp3", "mlp5", "mlp7", "lenet5", "alexnet"]

# Paper Table I rows (reported next to measured surrogate metrics).
PAPER_TABLE1 = {
    "exact": {"mae_pct": 0.0, "wce_pct": 0.0, "mre_pct": 0.0, "ep_pct": 0.0},
    "mul8s_1KVP": {"mae_pct": 0.051, "wce_pct": 0.21, "mre_pct": 2.73, "ep_pct": 74.80},
    "mul8s_1KV9": {"mae_pct": 0.0064, "wce_pct": 0.026, "mre_pct": 0.90, "ep_pct": 68.75},
    "mul8s_1KV8": {"mae_pct": 0.0018, "wce_pct": 0.0076, "mre_pct": 0.28, "ep_pct": 50.00},
}
# Paper Table II baselines (for side-by-side reporting only).
PAPER_TABLE2 = {"mlp3": 80.40, "lenet5": 85.80, "alexnet": 78.50, "mlp7": 98.80, "mlp5": 86.30}


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants matters: the default HLO printer elides big
    # constants as `constant({...})`, which the rust-side text parser
    # happily parses into garbage weights.
    return comp.as_hlo_text(print_large_constants=True)


def _train_cached(net: str, cache_dir: str, log) -> list:
    """Train or load cached float params for `net`."""
    path = os.path.join(cache_dir, f"{net}.params.nbin")
    arch = ARCHS[net]
    n_comp = len(arch.computing_layers)
    if os.path.exists(path):
        t = nbin.read_nbin(path)
        params = [(t[f"p{i}.w"], t[f"p{i}.b"]) for i in range(n_comp)]
        log(f"[aot:{net}] loaded cached float params")
        return params
    params = train.train(net, log=log)
    tensors = {}
    for i, (w, b) in enumerate(params):
        tensors[f"p{i}.w"] = w.astype(np.float32)
        tensors[f"p{i}.b"] = b.astype(np.float32)
    os.makedirs(cache_dir, exist_ok=True)
    nbin.write_nbin(path, tensors)
    return params


def _fault_parity_cases(q, x_q, exact_lut, rng):
    """Pinned single-bit-flip cases: (layer, neuron, bit) -> predictions."""
    sites = []
    preds = []
    for _ in range(FAULT_SAMPLES):
        li = int(rng.integers(0, len(q.qlayers)))
        shape = q.act_shapes[li]
        neuron = int(rng.integers(0, int(np.prod(shape))))
        bit = int(rng.integers(0, 8))
        masks = [None] * len(q.qlayers)
        m = np.zeros(shape, np.int8)
        m.reshape(-1)[neuron] = np.int8(np.uint8(1 << bit).view(np.int8))
        masks[li] = m
        p = predict_int(
            q,
            x_q[:EXPECTED_N],
            [exact_lut] * len(q.qlayers),
            masks=masks,
            batch=EXPECTED_N,
        )
        sites.append([li, neuron, bit])
        preds.append(p)
    return np.array(sites, np.int32), np.stack(preds).astype(np.int32)


def build(out_dir: str, nets=None, log=print) -> None:
    t_start = time.time()
    os.makedirs(out_dir, exist_ok=True)
    lut_dir = os.path.join(out_dir, "luts")
    os.makedirs(lut_dir, exist_ok=True)
    cache_dir = os.path.join(out_dir, ".train_cache")
    nets = nets or NETS

    # --- multipliers -------------------------------------------------------
    rows = luts.catalog_report()
    for m in luts.CATALOG:
        nbin.write_nbin(os.path.join(lut_dir, f"{m.name}.nbin"), {"lut": m.lut()})
    with open(os.path.join(out_dir, "multipliers.json"), "w") as f:
        json.dump(
            {"measured": rows, "paper_table1": PAPER_TABLE1, "paper_axms": luts.PAPER_AXMS},
            f,
            indent=1,
        )
    log(f"[aot] wrote {len(luts.CATALOG)} multiplier LUTs")
    exact_lut = luts.by_name("exact").lut()

    # --- datasets (quantized test splits, shared across nets) -------------
    test_sets = {}
    for ds in sorted({ARCHS[n].dataset for n in nets}):
        x, y = datasets.load(ds, "test", TEST_N)
        x_q = quantize_images(x, INPUT_SCALE)
        nbin.write_nbin(
            os.path.join(out_dir, f"{ds}.test.nbin"),
            {"x_q": x_q, "labels": y.astype(np.int32)},
        )
        test_sets[ds] = (x_q, y)
        log(f"[aot] dataset {ds}: {TEST_N} test images quantized (s_in=1/127)")

    # --- per-network pipeline ---------------------------------------------
    manifest = {
        "nets": {},
        "input_scale": INPUT_SCALE,
        "test_n": TEST_N,
        "lower_batch": LOWER_BATCH,
        "expected_n": EXPECTED_N,
        "paper_table2": PAPER_TABLE2,
    }
    for net in nets:
        arch = ARCHS[net]
        params = _train_cached(net, cache_dir, log)
        x_q, y = test_sets[arch.dataset]

        xf, yf = datasets.load(arch.dataset, "test", TEST_N)
        float_acc = train.eval_float(net, params, xf, yf)

        calib_x, _ = datasets.load(arch.dataset, "train", CALIB_N)
        q = quantize_net(arch, params, calib_x, input_scale=INPUT_SCALE)
        n_comp = len(q.qlayers)
        q_acc = accuracy_int(q, x_q, y, [exact_lut] * n_comp)
        log(
            f"[aot:{net}] float_acc={float_acc * 100:.2f}% quant_acc={q_acc * 100:.2f}% "
            f"(paper base {PAPER_TABLE2.get(net, float('nan'))}%)"
        )

        with open(os.path.join(out_dir, f"{net}.meta.json"), "w") as f:
            json.dump(qnet_meta(q), f, indent=1)
        nbin.write_nbin(os.path.join(out_dir, f"{net}.weights.nbin"), qnet_tensors(q))

        # Pinned parity artifacts for the rust engine.
        pred_exact = predict_int(q, x_q[:EXPECTED_N], [exact_lut] * n_comp, batch=EXPECTED_N)
        kvp_lut = luts.by_name("mul8s_1kvp_s").lut()
        pred_axm = predict_int(q, x_q[:EXPECTED_N], [kvp_lut] * n_comp, batch=EXPECTED_N)
        rng = np.random.default_rng(4242 + len(net))
        sites, pred_fault = _fault_parity_cases(q, x_q, exact_lut, rng)
        nbin.write_nbin(
            os.path.join(out_dir, f"{net}.expected.nbin"),
            {
                "pred_exact": pred_exact,
                "pred_axm_kvp": pred_axm,
                "fault_sites": sites,
                "pred_fault": pred_fault,
            },
        )

        # Lower the Pallas-kernel graph to HLO text.
        fn, args = build_lowerable(q, LOWER_BATCH)
        lowered = jax.jit(fn).lower(*args)
        hlo = to_hlo_text(lowered)
        with open(os.path.join(out_dir, f"{net}.hlo.txt"), "w") as f:
            f.write(hlo)
        log(f"[aot:{net}] lowered HLO ({len(hlo)} chars)")

        manifest["nets"][net] = {
            "dataset": arch.dataset,
            "float_acc": float_acc,
            "quant_acc": q_acc,
            "paper_quant_acc": PAPER_TABLE2.get(net),
            "n_comp_layers": n_comp,
            "config_template": arch.config_template,
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] done in {time.time() - t_start:.1f}s -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--nets", default=",".join(NETS))
    args = ap.parse_args()
    build(args.out, nets=[n for n in args.nets.split(",") if n])


if __name__ == "__main__":
    main()
