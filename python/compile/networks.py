"""Model zoo: the paper's five case-study networks, float32 JAX forward.

Architectures follow the paper's layer-configuration strings exactly:
computing layers (conv/dense) are the approximation sites, dashes mark the
non-computational pool positions (Table III):

  mlp3     "111"            3 dense layers                    (synmnist)
  mlp5     "11111"          5 dense layers                    (synmnist)
  mlp7     "1111111"        7 dense layers                    (synmnist)
  lenet5   "1-1-111"        conv P conv P fc fc fc            (synmnist)
  alexnet  "1-1-11-1-111"   c1 P c2 P c3 c4 P c5 P fc fc fc   (syncifar)

AlexNet is the CIFAR-scale variant (5 convs + 3 FCs, pools after
c1/c2/c4/c5) with channel counts sized for the 1-core build host; DESIGN.md
§2 documents the scaling.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Layer descriptors (plain tuples so they serialize trivially):
#   ("flatten",)
#   ("pool", size)
#   ("dense", in_features, out_features, relu)
#   ("conv", in_ch, out_ch, k, stride, pad, relu)


@dataclass(frozen=True)
class Arch:
    name: str
    dataset: str
    input_shape: Tuple[int, int, int]  # (C, H, W)
    layers: Tuple[tuple, ...]

    @property
    def computing_layers(self) -> List[int]:
        return [i for i, l in enumerate(self.layers) if l[0] in ("dense", "conv")]

    @property
    def config_template(self) -> str:
        """Paper-style configuration string template with 'x' per computing
        layer and '-' per pool."""
        out = []
        for l in self.layers:
            if l[0] in ("dense", "conv"):
                out.append("x")
            elif l[0] == "pool":
                out.append("-")
        return "".join(out)


ARCHS = {
    "mlp3": Arch(
        "mlp3",
        "synmnist",
        (1, 28, 28),
        (
            ("flatten",),
            ("dense", 784, 64, True),
            ("dense", 64, 32, True),
            ("dense", 32, 10, False),
        ),
    ),
    "mlp5": Arch(
        "mlp5",
        "synmnist",
        (1, 28, 28),
        (
            ("flatten",),
            ("dense", 784, 128, True),
            ("dense", 128, 64, True),
            ("dense", 64, 48, True),
            ("dense", 48, 32, True),
            ("dense", 32, 10, False),
        ),
    ),
    "mlp7": Arch(
        "mlp7",
        "synmnist",
        (1, 28, 28),
        (
            ("flatten",),
            ("dense", 784, 192, True),
            ("dense", 192, 128, True),
            ("dense", 128, 96, True),
            ("dense", 96, 64, True),
            ("dense", 64, 48, True),
            ("dense", 48, 32, True),
            ("dense", 32, 10, False),
        ),
    ),
    "lenet5": Arch(
        "lenet5",
        "synmnist",
        (1, 28, 28),
        (
            ("conv", 1, 6, 5, 1, 0, True),
            ("pool", 2),
            ("conv", 6, 16, 5, 1, 0, True),
            ("pool", 2),
            ("flatten",),
            ("dense", 256, 120, True),
            ("dense", 120, 84, True),
            ("dense", 84, 10, False),
        ),
    ),
    "alexnet": Arch(
        "alexnet",
        "syncifar",
        (3, 32, 32),
        (
            ("conv", 3, 16, 3, 1, 1, True),
            ("pool", 2),
            ("conv", 16, 32, 3, 1, 1, True),
            ("pool", 2),
            ("conv", 32, 48, 3, 1, 1, True),
            ("conv", 48, 48, 3, 1, 1, True),
            ("pool", 2),
            ("conv", 48, 64, 3, 1, 1, True),
            ("pool", 2),
            ("flatten",),
            ("dense", 256, 96, True),
            ("dense", 96, 48, True),
            ("dense", 48, 10, False),
        ),
    ),
}

PAPER_NETS = ["mlp3", "lenet5", "alexnet"]  # Table II / Table III set
MLP_CASE_STUDY = ["mlp3", "mlp5", "mlp7"]  # Table IV set


def init_params(arch: Arch, seed: int) -> List[Tuple[np.ndarray, np.ndarray]]:
    """He-normal init; returns [(w, b)] per computing layer.

    Dense w: [in, out]; conv w: [out_ch, in_ch, k, k] (OIHW, the lax.conv
    layout)."""
    rng = np.random.default_rng(seed)
    params = []
    for l in arch.layers:
        if l[0] == "dense":
            _, fin, fout, _ = l
            w = rng.normal(0, np.sqrt(2.0 / fin), size=(fin, fout)).astype(np.float32)
            params.append((w, np.zeros(fout, np.float32)))
        elif l[0] == "conv":
            _, cin, cout, k, _, _, _ = l
            fan_in = cin * k * k
            w = rng.normal(0, np.sqrt(2.0 / fan_in), size=(cout, cin, k, k)).astype(
                np.float32
            )
            params.append((w, np.zeros(cout, np.float32)))
    return params


def _maxpool2(x: jnp.ndarray, size: int) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, 1, size, size),
        (1, 1, size, size),
        "VALID",
    )


def forward_float(arch: Arch, params: Sequence, x: jnp.ndarray, collect: bool = False):
    """Float forward. x: [B, C, H, W]. Returns logits [B, 10]; with
    collect=True also returns the post-activation tensor of every computing
    layer (for quantization calibration)."""
    acts = []
    pi = 0
    for l in arch.layers:
        kind = l[0]
        if kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif kind == "pool":
            x = _maxpool2(x, l[1])
        elif kind == "dense":
            w, b = params[pi]
            pi += 1
            x = x @ w + b
            if l[3]:
                x = jax.nn.relu(x)
            acts.append(x)
        elif kind == "conv":
            _, cin, cout, k, stride, pad, relu = l
            w, b = params[pi]
            pi += 1
            x = jax.lax.conv_general_dilated(
                x,
                w,
                window_strides=(stride, stride),
                padding=[(pad, pad), (pad, pad)],
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )
            x = x + b[None, :, None, None]
            if relu:
                x = jax.nn.relu(x)
            acts.append(x)
        else:
            raise ValueError(kind)
    if collect:
        return x, acts
    return x


def activation_shapes(arch: Arch) -> List[Tuple[int, ...]]:
    """Per-computing-layer output shape (without batch dim), by dry-run."""
    x = jnp.zeros((1, *arch.input_shape), jnp.float32)
    params = init_params(arch, 0)
    _, acts = forward_float(arch, params, x, collect=True)
    return [tuple(a.shape[1:]) for a in acts]
