"""Build-time training loop (Keras stand-in): plain-JAX Adam + cross-entropy.

The image has no optax/flax; Adam is ~25 lines. Training is deterministic
given the seeds in `TRAIN_CFG` and runs once per network — `aot.py` caches
trained parameters under artifacts/.train_cache/ and skips retraining when
the cache matches the architecture fingerprint.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .networks import ARCHS, Arch, forward_float, init_params

TRAIN_CFG = {
    # net: (train_n, epochs, batch, lr, seed)
    "mlp3": (8000, 12, 100, 1e-3, 11),
    "mlp5": (8000, 12, 100, 1e-3, 12),
    "mlp7": (8000, 12, 100, 1e-3, 13),
    "lenet5": (8000, 8, 100, 1e-3, 14),
    "alexnet": (8000, 10, 100, 1e-3, 15),
}


def _loss_fn(arch: Arch, params, x, y):
    logits = forward_float(arch, params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(x.shape[0]), y])


def train(net: str, log=print) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Train `net` on its synthetic dataset; returns float params."""
    arch = ARCHS[net]
    train_n, epochs, batch, lr, seed = TRAIN_CFG[net]
    xs, ys = datasets.load(arch.dataset, "train", train_n)
    params = [(jnp.asarray(w), jnp.asarray(b)) for w, b in init_params(arch, seed)]

    # Adam state
    m = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    v = [(jnp.zeros_like(w), jnp.zeros_like(b)) for w, b in params]
    b1, b2, eps = 0.9, 0.999, 1e-8

    grad_fn = jax.jit(jax.value_and_grad(lambda p, x, y: _loss_fn(arch, p, x, y)))

    @jax.jit
    def adam_step(params, m, v, grads, t):
        new_p, new_m, new_v = [], [], []
        for (w, b), (mw, mb), (vw, vb), (gw, gb) in zip(params, m, v, grads):
            mw = b1 * mw + (1 - b1) * gw
            mb = b1 * mb + (1 - b1) * gb
            vw = b2 * vw + (1 - b2) * gw**2
            vb = b2 * vb + (1 - b2) * gb**2
            mhw, mhb = mw / (1 - b1**t), mb / (1 - b1**t)
            vhw, vhb = vw / (1 - b2**t), vb / (1 - b2**t)
            new_p.append((w - lr * mhw / (jnp.sqrt(vhw) + eps), b - lr * mhb / (jnp.sqrt(vhb) + eps)))
            new_m.append((mw, mb))
            new_v.append((vw, vb))
        return new_p, new_m, new_v

    rng = np.random.default_rng(seed + 777)
    n_batches = train_n // batch
    t0 = time.time()
    step = 0
    for ep in range(epochs):
        order = rng.permutation(train_n)
        ep_loss = 0.0
        for bi in range(n_batches):
            idx = order[bi * batch : (bi + 1) * batch]
            step += 1
            loss, grads = grad_fn(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
            params, m, v = adam_step(params, m, v, grads, jnp.float32(step))
            ep_loss += float(loss)
        log(f"[train:{net}] epoch {ep + 1}/{epochs} loss={ep_loss / n_batches:.4f} ({time.time() - t0:.1f}s)")
    return [(np.asarray(w), np.asarray(b)) for w, b in params]


def eval_float(net: str, params, xs: np.ndarray, ys: np.ndarray, batch: int = 200) -> float:
    arch = ARCHS[net]
    fwd = jax.jit(lambda p, x: jnp.argmax(forward_float(arch, p, x), axis=-1))
    jp = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params]
    correct = 0
    for i in range(0, len(xs), batch):
        pred = fwd(jp, jnp.asarray(xs[i : i + batch]))
        correct += int((np.asarray(pred) == ys[i : i + batch]).sum())
    return correct / len(xs)
