"""Approximate 8-bit signed multiplier library (EvoApproxLib stand-in).

Every multiplier — exact or approximate — is materialized as a 64K-entry
int32 lookup table `lut[(a_u8 << 8) | b_u8] = mult(a, b)` where `a_u8`,
`b_u8` are the two's-complement bytes of the signed operands. The whole
framework (Pallas kernel, JAX graph, rust simnet engine, PJRT executable)
consumes multipliers only through such LUTs, so an approximate multiplier
is *data*, never code — one compiled artifact serves every configuration.

The paper uses three CGP-evolved EvoApproxLib circuits (mul8s_1KVP,
mul8s_1KV9, mul8s_1KV8, Table I). Their exact netlists are not available in
this offline image, so we build *behavioral surrogates* from classic
approximate-multiplier families and calibrate the family/parameter choice
to the paper's reported error profile (see DESIGN.md §2). Measured metrics
(MAE/WCE/MRE/EP over the exhaustive 2^16 input space) are emitted into
`artifacts/multipliers.json` and reported side-by-side with the paper's.

Families implemented:
  * exact          — the golden array multiplier.
  * bam(k)         — broken-array multiplier: all partial-product bits with
                     weight < 2^k are dropped (on magnitudes; sign is
                     reapplied). Classic AxC lower-part-OR/drop family.
  * trunc(k)       — operand LSB truncation: the k low bits of each operand
                     magnitude are zeroed before the exact multiply.
  * rndpp(k)       — product rounded to the nearest multiple of 2^k.
  * mitchell       — Mitchell logarithmic multiplier (ablation A3).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List

import numpy as np

# ---------------------------------------------------------------------------
# Family builders. Each returns the full product plane P[a+128, b+128] i32
# for signed a, b in [-128, 127] (index = two's-complement byte value would
# reorder rows; we build in signed order then roll into byte order).
# ---------------------------------------------------------------------------


def _signed_grid() -> tuple[np.ndarray, np.ndarray]:
    a = np.arange(-128, 128, dtype=np.int32)
    return a[:, None], a[None, :]


def plane_exact() -> np.ndarray:
    a, b = _signed_grid()
    return (a * b).astype(np.int32)


def plane_bam(k: int) -> np.ndarray:
    """Broken-array multiplier: drop partial-product bits a_i*b_j with
    i + j < k, computed on magnitudes, sign reapplied."""
    a, b = _signed_grid()
    am, bm = np.abs(a), np.abs(b)
    sign = np.sign(a) * np.sign(b)
    exact = am * bm
    dropped = np.zeros_like(exact)
    for i in range(8):
        ai = (am >> i) & 1
        for j in range(8):
            if i + j < k:
                bj = (bm >> j) & 1
                dropped = dropped + (ai * bj) * (1 << (i + j))
    return (sign * (exact - dropped)).astype(np.int32)


def plane_trunc(k: int) -> np.ndarray:
    a, b = _signed_grid()
    am, bm = np.abs(a), np.abs(b)
    sign = np.sign(a) * np.sign(b)
    mask = ~((1 << k) - 1)
    return (sign * ((am & mask) * (bm & mask))).astype(np.int32)


def plane_rndpp(k: int) -> np.ndarray:
    a, b = _signed_grid()
    p = a * b
    half = 1 << (k - 1)
    return (((p + half) >> k) << k).astype(np.int32)


def plane_mitchell() -> np.ndarray:
    """Mitchell logarithmic multiplier: p ≈ 2^(log2~a + log2~b) with linear
    mantissa approximation; zero operands map to zero."""
    a, b = _signed_grid()
    am, bm = np.abs(a).astype(np.float64), np.abs(b).astype(np.float64)
    sign = np.sign(a) * np.sign(b)

    def mlog(x: np.ndarray) -> np.ndarray:
        # characteristic + linear mantissa; x >= 1
        k = np.floor(np.log2(np.maximum(x, 1)))
        return k + (x / np.exp2(k) - 1.0)

    la, lb = mlog(np.maximum(am, 1)), mlog(np.maximum(bm, 1))
    s = la + lb
    kk = np.floor(s)
    approx = np.exp2(kk) * (1.0 + (s - kk))
    approx = np.where((am == 0) | (bm == 0), 0.0, approx)
    return (sign * np.round(approx)).astype(np.int32)


# ---------------------------------------------------------------------------
# Catalog
# ---------------------------------------------------------------------------


@dataclass
class Multiplier:
    name: str  # our surrogate name (stable identifier used everywhere)
    paper_name: str  # the EvoApproxLib circuit it stands in for ("" if none)
    family: str
    param: int
    power_mw: float  # paper Table I (inputs to the HW cost model)
    area_um2: float
    builder: Callable[[], np.ndarray] = field(repr=False)

    def plane(self) -> np.ndarray:
        return self.builder()

    def lut(self) -> np.ndarray:
        """64K-entry LUT in two's-complement byte order:
        lut[(a_u8 << 8) | b_u8] = mult(a, b)."""
        plane = self.plane()
        # signed order -128..127 -> byte order 0..255 (0..127, -128..-1)
        reordered = np.roll(np.roll(plane, -128, axis=0), -128, axis=1)
        return reordered.reshape(-1).astype(np.int32)


# Calibration (see DESIGN.md §2): bam(2) ~ mul8s_1KV8, bam(3) ~ mul8s_1KV9,
# bam(4) ~ mul8s_1KVP. Power/area are taken from the paper's Table I because
# they parameterize the hardware model, not the arithmetic.
CATALOG: List[Multiplier] = [
    Multiplier("exact", "exact", "exact", 0, 0.425, 729.8, plane_exact),
    Multiplier("mul8s_1kvp_s", "mul8s_1KVP", "bam", 4, 0.363, 635.0, lambda: plane_bam(4)),
    Multiplier("mul8s_1kv9_s", "mul8s_1KV9", "bam", 3, 0.410, 685.2, lambda: plane_bam(3)),
    Multiplier("mul8s_1kv8_s", "mul8s_1KV8", "bam", 2, 0.422, 711.0, lambda: plane_bam(2)),
    # Ablation-only families (A3) — not part of the paper's Table I set.
    Multiplier("trunc2", "", "trunc", 2, 0.400, 690.0, lambda: plane_trunc(2)),
    Multiplier("rndpp4", "", "rndpp", 4, 0.395, 680.0, lambda: plane_rndpp(4)),
    Multiplier("mitchell", "", "mitchell", 0, 0.310, 560.0, plane_mitchell),
]

PAPER_AXMS = ["mul8s_1kvp_s", "mul8s_1kv9_s", "mul8s_1kv8_s"]


def by_name(name: str) -> Multiplier:
    for m in CATALOG:
        if m.name == name:
            return m
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Exhaustive error metrics (EvoApproxLib conventions for mul8s: magnitudes
# normalized by 2^15 when reported as percentages).
# ---------------------------------------------------------------------------


def error_metrics(plane: np.ndarray) -> Dict[str, float]:
    exact = plane_exact().astype(np.int64)
    approx = plane.astype(np.int64)
    err = approx - exact
    abs_err = np.abs(err)
    nonzero = exact != 0
    rel = np.zeros_like(abs_err, dtype=np.float64)
    rel[nonzero] = abs_err[nonzero] / np.abs(exact[nonzero])
    # EvoApprox counts |exact|=0 cells as relative error = |err| (capped 1)
    rel[~nonzero] = np.minimum(abs_err[~nonzero], 1)
    return {
        "mae": float(abs_err.mean()),
        "wce": float(abs_err.max()),
        "mre_pct": float(rel.mean() * 100.0),
        "ep_pct": float((err != 0).mean() * 100.0),
        "mae_pct": float(abs_err.mean() / 2**15 * 100.0),
        "wce_pct": float(abs_err.max() / 2**15 * 100.0),
    }


def catalog_report() -> List[Dict]:
    """Measured Table-I-style rows for every multiplier in the catalog."""
    rows = []
    for m in CATALOG:
        met = error_metrics(m.plane())
        rows.append(
            {
                "name": m.name,
                "paper_name": m.paper_name,
                "family": m.family,
                "param": m.param,
                "power_mw": m.power_mw,
                "area_um2": m.area_um2,
                **met,
            }
        )
    return rows
