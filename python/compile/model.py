"""Layer 2 — quantized inference graphs for the model zoo.

`forward_int` is the single definition of the integer network semantics;
it is parameterized by the GEMM implementation so the same code path serves:

  * the jnp reference (`kernels.ref.axgemm_ref`) — build-time accuracy
    evaluation (Table II) and the expected-prediction artifacts that pin
    the rust engine;
  * the Pallas kernel (`kernels.axgemm.axgemm`) — the variant that is
    AOT-lowered to HLO text and executed by the rust PJRT runtime.

Graph inputs are *data, not code*: one multiplier LUT per computing layer
(any approximation configuration = choice of LUT tensors) and one XOR fault
mask per computing-layer activation (all-zeros = fault-free; one set bit =
the paper's single-bit-flip fault). A single lowered executable therefore
serves the entire 2^n × |AxM| design space and every fault site.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.axgemm import axgemm
from .quantize import QNet

GemmFn = Callable[[jnp.ndarray, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def forward_int(
    q: QNet,
    x_q: jnp.ndarray,
    luts: Sequence[jnp.ndarray],
    masks: Optional[Sequence[Optional[jnp.ndarray]]] = None,
    gemm: GemmFn = ref.axgemm_ref,
) -> jnp.ndarray:
    """Integer forward pass.

    x_q: int8 [B, C, H, W]; luts: one int32 [65536] per computing layer;
    masks: optional int8 XOR masks, one per computing layer (None entries
    allowed). Returns int8 logits [B, 10].
    """
    n_comp = len(q.qlayers)
    assert len(luts) == n_comp, (len(luts), n_comp)
    if masks is None:
        masks = [None] * n_comp

    x = x_q
    b = x_q.shape[0]
    ci = 0
    for l in q.arch.layers:
        kind = l[0]
        if kind == "flatten":
            x = x.reshape(b, -1)
        elif kind == "pool":
            x = ref.maxpool_i8(x, l[1])
        else:
            ql = q.qlayers[ci]
            if ql.kind == "dense":
                acc = gemm(x, jnp.asarray(ql.w_q), luts[ci])  # [B, N]
                acc = acc + jnp.asarray(ql.b_q)[None, :]
                y = ref.requantize(acc, ql.m0, ql.nshift, ql.relu)
            else:
                cols = ref.im2col(x, ql.ksize, ql.stride, ql.pad)  # [B*OH*OW, K]
                acc = gemm(cols, jnp.asarray(ql.w_q), luts[ci])
                acc = acc + jnp.asarray(ql.b_q)[None, :]
                y = ref.requantize(acc, ql.m0, ql.nshift, ql.relu)
                c_out, oh, ow = q.act_shapes[ci]
                y = y.reshape(b, oh, ow, c_out).transpose(0, 3, 1, 2)
            if masks[ci] is not None:
                y = jnp.bitwise_xor(y, masks[ci])
            x = y
            ci += 1
    return x  # int8 logits [B, 10]


def predict_int(
    q: QNet,
    x_q: np.ndarray,
    luts: Sequence[np.ndarray],
    masks: Optional[Sequence[Optional[np.ndarray]]] = None,
    gemm: GemmFn = ref.axgemm_ref,
    batch: int = 100,
) -> np.ndarray:
    """Batched argmax predictions (first-max tie-breaking, matching rust)."""
    jl = [jnp.asarray(l) for l in luts]
    preds = []
    for i in range(0, len(x_q), batch):
        xb = jnp.asarray(x_q[i : i + batch])
        mb = None
        if masks is not None:
            # per-image masks of shape act_shape, broadcast over the batch
            mb = [
                None
                if m is None
                else jnp.asarray(np.broadcast_to(m, (xb.shape[0], *m.shape)).copy())
                for m in masks
            ]
        logits = forward_int(q, xb, jl, mb, gemm=gemm)
        preds.append(np.asarray(jnp.argmax(logits, axis=-1)))
    return np.concatenate(preds).astype(np.int32)


def accuracy_int(
    q: QNet,
    x_q: np.ndarray,
    labels: np.ndarray,
    luts: Sequence[np.ndarray],
    gemm: GemmFn = ref.axgemm_ref,
    batch: int = 100,
) -> float:
    preds = predict_int(q, x_q, luts, gemm=gemm, batch=batch)
    return float((preds == labels).mean())


# ---------------------------------------------------------------------------
# AOT lowering entry point
# ---------------------------------------------------------------------------


def build_lowerable(q: QNet, batch: int):
    """Returns (fn, example_args) for jax.jit(...).lower().

    fn(x_q, lut_0..lut_{L-1}, mask_0..mask_{L-1}) -> int8 logits [batch, 10],
    using the Pallas kernel so L1 lowers into the same HLO module.
    """
    n_comp = len(q.qlayers)

    def fn(x_q, *rest):
        luts = rest[:n_comp]
        masks = rest[n_comp:]
        return (forward_int(q, x_q, luts, masks, gemm=axgemm),)

    args = [jax.ShapeDtypeStruct((batch, *q.arch.input_shape), jnp.int8)]
    args += [jax.ShapeDtypeStruct((65536,), jnp.int32) for _ in range(n_comp)]
    args += [
        jax.ShapeDtypeStruct((batch, *q.act_shapes[i]), jnp.int8) for i in range(n_comp)
    ]
    return fn, args
