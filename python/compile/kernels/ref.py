"""Pure-jnp oracle for the LUT-multiplier GEMM and its integer plumbing.

This is the correctness ground truth: the Pallas kernel (axgemm.py), the
lowered HLO executable and the rust simnet engine are all pinned to these
semantics by tests. Everything here is exact integer arithmetic — no float
appears between input quantization and the argmax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Vectorizing the whole [M, K, N] index cube is fastest for small layers but
# O(M*K*N) memory; above this budget we scan over K instead.
_CUBE_BUDGET = 4_000_000


def axgemm_ref(a: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray) -> jnp.ndarray:
    """LUT-multiplier GEMM oracle.

    a: int8 [M, K] activations; w: int8 [K, N] weights; lut: int32 [65536]
    with lut[(a_u8 << 8) | w_u8] = mult(a, w). Returns int32 [M, N] with
    acc[m, n] = sum_k lut(a[m, k], w[k, n]).
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    a32 = a.astype(jnp.int32) & 0xFF
    w32 = w.astype(jnp.int32) & 0xFF
    if m * k * n <= _CUBE_BUDGET:
        idx = (a32[:, :, None] << 8) | w32[None, :, :]
        return jnp.take(lut, idx, axis=0).sum(axis=1, dtype=jnp.int32)

    def body(acc, kk):
        col = jax.lax.dynamic_slice_in_dim(a32, kk, 1, axis=1)  # [M, 1]
        row = jax.lax.dynamic_slice_in_dim(w32, kk, 1, axis=0)  # [1, N]
        idx = (col << 8) | row
        return acc + jnp.take(lut, idx, axis=0), None

    acc0 = jnp.zeros((m, n), jnp.int32)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(k))
    return acc


def requantize(acc: jnp.ndarray, m0: int, nshift: int, relu: bool) -> jnp.ndarray:
    """int32 accumulator -> int8 activation.

    y = clamp_i8((acc * m0 + 2^(n-1)) >> n), then ReLU on the quantized
    value. Requires jax x64 (enabled by compile/__init__.py)."""
    y = (acc.astype(jnp.int64) * jnp.int64(m0) + (jnp.int64(1) << (nshift - 1))) >> nshift
    y = jnp.clip(y, -128, 127).astype(jnp.int8)
    if relu:
        y = jnp.maximum(y, jnp.int8(0))
    return y


def im2col(x: jnp.ndarray, k: int, stride: int, pad: int) -> jnp.ndarray:
    """int8 [B, C, H, W] -> int8 [B*OH*OW, C*k*k] patch matrix.

    Patch index ordering is K = (ci*k + ky)*k + kx; rows are ordered
    (b, oy, ox). Zero padding is exact for symmetric quantization
    (zero-point = 0)."""
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            cols.append(
                jax.lax.slice(
                    xp,
                    (0, 0, ky, kx),
                    (b, c, ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                    (1, 1, stride, stride),
                )
            )
    stacked = jnp.stack(cols, axis=2)  # [B, C, k*k, OH, OW]
    return (
        stacked.reshape(b, c * k * k, oh * ow).transpose(0, 2, 1).reshape(b * oh * ow, c * k * k)
    )


def maxpool_i8(x: jnp.ndarray, size: int) -> jnp.ndarray:
    """int8 [B, C, H, W] max pooling (size x size, stride = size)."""
    return jax.lax.reduce_window(
        x,
        jnp.int8(-128),
        jax.lax.max,
        (1, 1, size, size),
        (1, 1, size, size),
        "VALID",
    )
