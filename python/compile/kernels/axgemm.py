"""Layer 1 — the Pallas LUT-multiplier GEMM kernel (the hot spot).

Every multiply in the whole framework funnels through this kernel: an
int8×int8 GEMM whose scalar product is a gather into a 64K-entry i32 LUT
(the behavioral model of an exact or approximate multiplier), accumulated
in int32.

TPU mapping (DESIGN.md §3): the 256 KiB LUT is held VMEM-resident across
the whole grid (its BlockSpec index map is constant), while BlockSpec
streams M-tiles of the (im2col'ed) activations from HBM; the K-loop runs
inside the kernel over the VMEM tile. Approximate multiplication is data,
so the MXU systolic array is replaced by a gather+add pipeline — the
BlockSpec schedule plays the role the paper's HLS unroll pragmas play on
the FPGA.

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT client cannot execute. Correctness is pinned
to kernels/ref.py by python/tests/test_kernel.py (hypothesis sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default M-tile: 128 rows keeps the working set (a-tile + out-tile + LUT)
# within a ~512 KiB VMEM budget for every layer shape in the model zoo; see
# DESIGN.md §8 for the footprint table.
BLOCK_M = 128


def _kernel(a_ref, w_ref, lut_ref, o_ref):
    """One (BLOCK_M, N) output tile: K-loop of LUT gathers."""
    a32 = a_ref[...].astype(jnp.int32) & 0xFF  # [bm, K]
    w32 = w_ref[...].astype(jnp.int32) & 0xFF  # [K, N]
    lut = lut_ref[...]
    bm = a32.shape[0]
    n = w32.shape[1]
    kdim = a32.shape[1]

    def body(k, acc):
        col = jax.lax.dynamic_slice_in_dim(a32, k, 1, axis=1)  # [bm, 1]
        row = jax.lax.dynamic_slice_in_dim(w32, k, 1, axis=0)  # [1, N]
        idx = (col << 8) | row  # [bm, N]
        return acc + jnp.take(lut, idx, axis=0)

    acc = jax.lax.fori_loop(0, kdim, body, jnp.zeros((bm, n), jnp.int32))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_m",))
def axgemm(a: jnp.ndarray, w: jnp.ndarray, lut: jnp.ndarray, block_m: int = BLOCK_M) -> jnp.ndarray:
    """Pallas LUT-GEMM: a int8 [M, K], w int8 [K, N], lut int32 [65536]
    -> int32 [M, N]. Semantics identical to kernels.ref.axgemm_ref."""
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    block_m = min(block_m, m)
    grid = ((m + block_m - 1) // block_m,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((65536,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, w, lut)
