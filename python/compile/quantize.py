"""Post-training full-integer 8-bit quantization (TFLite stand-in).

Scheme (documented in DESIGN.md §2, mirrored bit-for-bit by rust/src/simnet):

  * activations: symmetric per-tensor int8, scale s = max|x|/127 from a
    calibration batch; input images quantized the same way.
  * weights: symmetric per-tensor int8.
  * bias: int32 at scale s_in*s_w.
  * layer compute: acc_i32[j] = b_q[j] + sum_k LUT(a_q[k], w_q[k, j]);
    requantize with the gemmlowp-style fixed-point multiplier
        y = clamp_i8( (acc_i64 * m0 + 2^(n-1)) >> n ),   m0 = round(r·2^n),
    r = s_in*s_w/s_out, n chosen so m0 ∈ [2^30, 2^31) (capped at 62);
    ReLU applied on the quantized value; every computing layer output is an
    int8 "activation" — the paper's fault-injection site.

Weights are exported in GEMM layout: dense w[in, out]; conv w[K, out_ch]
with patch index K = (ci*k + ky)*k + kx — the same im2col ordering used by
the Pallas kernel, the jnp reference and the rust engine.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .networks import Arch, forward_float


def requant_params(r: float) -> Tuple[int, int]:
    """Fixed-point representation of real multiplier r: (m0, n) with
    m0 = round(r * 2^n), m0 in [2^30, 2^31) (n capped at 62)."""
    if r <= 0:
        raise ValueError(f"requant multiplier must be positive, got {r}")
    n = 30 - math.floor(math.log2(r))
    n = min(max(n, 0), 62)
    m0 = int(round(r * (1 << n)))
    if m0 >= 1 << 31:  # rounding pushed it over; renormalize
        m0 >>= 1
        n -= 1
    return m0, n


@dataclass
class QLayer:
    """One quantized computing layer in GEMM form."""

    kind: str  # "dense" | "conv"
    relu: bool
    w_q: np.ndarray  # int8 [K, N]
    b_q: np.ndarray  # int32 [N]
    s_in: float
    s_w: float
    s_out: float
    m0: int
    nshift: int
    # conv-only geometry (zeros for dense)
    in_ch: int = 0
    out_ch: int = 0
    ksize: int = 0
    stride: int = 0
    pad: int = 0


@dataclass
class QNet:
    name: str
    arch: Arch
    s_in: float  # input image scale
    qlayers: List[QLayer]  # one per computing layer, in order
    act_shapes: List[Tuple[int, ...]] = field(default_factory=list)

    def layer_struct(self) -> List[tuple]:
        """The full layer sequence with computing-layer indices resolved."""
        return list(self.arch.layers)


def _scale(max_abs: float) -> float:
    return max(float(max_abs), 1e-6) / 127.0


def quantize_net(
    arch: Arch,
    params,
    calib_x: np.ndarray,
    name: Optional[str] = None,
    input_scale: Optional[float] = None,
) -> QNet:
    """Post-training quantization against a float calibration batch.

    `input_scale` pins the image scale (the aot driver uses 1/127 so one
    quantized test set is shared by every net on a dataset)."""
    logits, acts = forward_float(
        arch, [(jnp.asarray(w), jnp.asarray(b)) for w, b in params], jnp.asarray(calib_x), collect=True
    )
    acts = [np.asarray(a) for a in acts]
    s_img = input_scale if input_scale is not None else _scale(np.abs(calib_x).max())

    qlayers: List[QLayer] = []
    s_in = s_img
    pi = 0
    for l in arch.layers:
        kind = l[0]
        if kind not in ("dense", "conv"):
            continue
        w, b = params[pi]
        a_out = acts[pi]
        pi += 1
        s_w = _scale(np.abs(w).max())
        s_out = _scale(np.abs(a_out).max())
        if kind == "dense":
            w_col = np.asarray(w)  # [in, out]
            relu = l[3]
            geom = dict(in_ch=0, out_ch=0, ksize=0, stride=0, pad=0)
        else:
            _, cin, cout, k, stride, pad, relu = l
            # OIHW -> [K, N] with K = (ci*k + ky)*k + kx
            w_col = np.asarray(w).transpose(1, 2, 3, 0).reshape(cin * k * k, cout)
            geom = dict(in_ch=cin, out_ch=cout, ksize=k, stride=stride, pad=pad)
        w_q = np.clip(np.round(w_col / s_w), -127, 127).astype(np.int8)
        b_q = np.round(np.asarray(b) / (s_in * s_w)).astype(np.int64)
        b_q = np.clip(b_q, -(2**31), 2**31 - 1).astype(np.int32)
        m0, nshift = requant_params(s_in * s_w / s_out)
        qlayers.append(
            QLayer(
                kind=kind,
                relu=bool(relu),
                w_q=w_q,
                b_q=b_q,
                s_in=float(s_in),
                s_w=float(s_w),
                s_out=float(s_out),
                m0=m0,
                nshift=nshift,
                **geom,
            )
        )
        s_in = s_out  # next layer consumes this activation

    from .networks import activation_shapes

    return QNet(
        name=name or arch.name,
        arch=arch,
        s_in=s_img,
        qlayers=qlayers,
        act_shapes=activation_shapes(arch),
    )


def quantize_images(x: np.ndarray, s_in: float) -> np.ndarray:
    return np.clip(np.round(x / s_in), -128, 127).astype(np.int8)


# ---------------------------------------------------------------------------
# Serialization to the artifact formats (meta dict + named tensors)
# ---------------------------------------------------------------------------


def qnet_meta(q: QNet) -> Dict:
    layers_meta = []
    ci = 0
    for l in q.arch.layers:
        kind = l[0]
        if kind == "flatten":
            layers_meta.append({"kind": "flatten"})
        elif kind == "pool":
            layers_meta.append({"kind": "pool", "size": l[1]})
        else:
            ql = q.qlayers[ci]
            layers_meta.append(
                {
                    "kind": ql.kind,
                    "comp_index": ci,
                    "relu": ql.relu,
                    "k_dim": int(ql.w_q.shape[0]),
                    "n_dim": int(ql.w_q.shape[1]),
                    "s_in": ql.s_in,
                    "s_w": ql.s_w,
                    "s_out": ql.s_out,
                    "m0": ql.m0,
                    "nshift": ql.nshift,
                    "in_ch": ql.in_ch,
                    "out_ch": ql.out_ch,
                    "ksize": ql.ksize,
                    "stride": ql.stride,
                    "pad": ql.pad,
                    "act_shape": list(q.act_shapes[ci]),
                }
            )
            ci += 1
    return {
        "name": q.name,
        "dataset": q.arch.dataset,
        "input_shape": list(q.arch.input_shape),
        "input_scale": q.s_in,
        "config_template": q.arch.config_template,
        "n_comp_layers": len(q.qlayers),
        "layers": layers_meta,
    }


def qnet_tensors(q: QNet) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for i, ql in enumerate(q.qlayers):
        out[f"l{i}.w"] = ql.w_q
        out[f"l{i}.b"] = ql.b_q
    return out
