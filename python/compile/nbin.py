"""nbin — tiny named-tensor binary container shared with the rust side.

The offline image has no serde/npz bridge, so artifacts (weights, LUTs,
datasets, expected predictions) are exchanged in this trivial format:

    magic   : 6 bytes  b"NBIN1\\x00"
    count   : u16 LE   number of entries
    entry   :
        name_len : u16 LE
        name     : utf-8 bytes
        dtype    : u8   (0=i8, 1=u8, 2=i32, 3=i64, 4=f32, 5=f64)
        ndim     : u8
        dims     : u32 LE * ndim
        nbytes   : u64 LE  (redundant, for integrity checking)
        payload  : raw little-endian data, C order

The rust reader/writer lives in rust/src/nbin.rs; `python/tests/test_nbin.py`
and the rust unit tests pin the format from both sides.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"NBIN1\x00"

_DTYPE_TO_CODE = {
    np.dtype(np.int8): 0,
    np.dtype(np.uint8): 1,
    np.dtype(np.int32): 2,
    np.dtype(np.int64): 3,
    np.dtype(np.float32): 4,
    np.dtype(np.float64): 5,
}
_CODE_TO_DTYPE = {v: k for k, v in _DTYPE_TO_CODE.items()}


def write_nbin(path: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write a dict of named numpy arrays to `path`.

    Dtypes must be one of the supported codes; arrays are stored C-ordered.
    """
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<H", len(tensors)))
        for name, arr in tensors.items():
            # note: ascontiguousarray would promote 0-d to 1-d; keep ndim
            arr = arr if (isinstance(arr, np.ndarray) and arr.ndim == 0) else np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPE_TO_CODE:
                raise ValueError(f"unsupported dtype {arr.dtype} for entry {name!r}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_TO_CODE[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            payload = arr.astype(arr.dtype.newbyteorder("<")).tobytes(order="C")
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def read_nbin(path: str) -> Dict[str, np.ndarray]:
    """Read an nbin file back into a dict of numpy arrays."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (count,) = struct.unpack("<H", f.read(2))
        for _ in range(count):
            (name_len,) = struct.unpack("<H", f.read(2))
            name = f.read(name_len).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = [struct.unpack("<I", f.read(4))[0] for _ in range(ndim)]
            (nbytes,) = struct.unpack("<Q", f.read(8))
            dtype = _CODE_TO_DTYPE[code]
            payload = f.read(nbytes)
            if len(payload) != nbytes:
                raise ValueError(f"{path}: truncated payload for {name!r}")
            arr = np.frombuffer(payload, dtype=dtype.newbyteorder("<")).astype(dtype)
            expected = int(np.prod(dims)) if dims else 1
            if arr.size != expected:
                raise ValueError(
                    f"{path}: entry {name!r} payload {arr.size} != dims {dims}"
                )
            out[name] = arr.reshape(dims)
    return out
