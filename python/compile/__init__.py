"""DeepAxe build path (compile-time only; never on the rust request path).

Enabling x64 here matters: the requantization fixed-point math is defined
on int64 and must match the rust engine bit-for-bit.
"""

import jax

jax.config.update("jax_enable_x64", True)
