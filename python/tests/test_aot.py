"""End-to-end build-path test: a scaled-down aot.build() on mlp3, checking
every artifact the rust side consumes."""

import json
import os

import numpy as np
import pytest

from compile import aot, nbin, train


@pytest.fixture(scope="module")
def built(tmp_path_factory, request):
    out = str(tmp_path_factory.mktemp("artifacts"))
    # Scale everything down: 1 training epoch, small test split.
    orig_cfg = dict(train.TRAIN_CFG)
    orig = (aot.TEST_N, aot.CALIB_N, aot.LOWER_BATCH, aot.EXPECTED_N, aot.FAULT_SAMPLES)
    train.TRAIN_CFG["mlp3"] = (600, 1, 100, 1e-3, 11)
    aot.TEST_N, aot.CALIB_N, aot.LOWER_BATCH, aot.EXPECTED_N, aot.FAULT_SAMPLES = 80, 64, 4, 16, 2
    try:
        aot.build(out, nets=["mlp3"], log=lambda *a: None)
    finally:
        train.TRAIN_CFG.update(orig_cfg)
        aot.TEST_N, aot.CALIB_N, aot.LOWER_BATCH, aot.EXPECTED_N, aot.FAULT_SAMPLES = orig
    return out


def test_manifest(built):
    with open(os.path.join(built, "manifest.json")) as f:
        man = json.load(f)
    assert "mlp3" in man["nets"]
    assert man["nets"]["mlp3"]["n_comp_layers"] == 3
    assert 0 <= man["nets"]["mlp3"]["quant_acc"] <= 1


def test_multipliers_json_and_luts(built):
    with open(os.path.join(built, "multipliers.json")) as f:
        m = json.load(f)
    names = {r["name"] for r in m["measured"]}
    assert {"exact", "mul8s_1kvp_s", "mul8s_1kv9_s", "mul8s_1kv8_s"} <= names
    for name in names:
        lut = nbin.read_nbin(os.path.join(built, "luts", f"{name}.nbin"))["lut"]
        assert lut.shape == (65536,) and lut.dtype == np.int32
    exact = nbin.read_nbin(os.path.join(built, "luts", "exact.nbin"))["lut"]
    # spot-check byte-order indexing
    assert exact[((5 & 0xFF) << 8) | (7 & 0xFF)] == 35
    assert exact[((-5 & 0xFF) << 8) | (7 & 0xFF)] == -35


def test_dataset_artifact(built):
    d = nbin.read_nbin(os.path.join(built, "synmnist.test.nbin"))
    assert d["x_q"].shape == (80, 1, 28, 28) and d["x_q"].dtype == np.int8
    assert d["labels"].shape == (80,) and d["labels"].dtype == np.int32


def test_meta_and_weights(built):
    with open(os.path.join(built, "mlp3.meta.json")) as f:
        meta = json.load(f)
    assert meta["n_comp_layers"] == 3
    assert meta["input_scale"] == pytest.approx(1 / 127)
    w = nbin.read_nbin(os.path.join(built, "mlp3.weights.nbin"))
    for i, l in enumerate([l for l in meta["layers"] if l["kind"] != "flatten"]):
        assert w[f"l{i}.w"].shape == (l["k_dim"], l["n_dim"])
        assert w[f"l{i}.b"].shape == (l["n_dim"],)


def test_expected_predictions(built):
    e = nbin.read_nbin(os.path.join(built, "mlp3.expected.nbin"))
    assert e["pred_exact"].shape == (16,)
    assert e["pred_axm_kvp"].shape == (16,)
    assert e["fault_sites"].shape == (2, 3)
    assert e["pred_fault"].shape == (2, 16)
    assert e["pred_exact"].min() >= 0 and e["pred_exact"].max() <= 9


def test_hlo_text_loadable_format(built):
    hlo = open(os.path.join(built, "mlp3.hlo.txt")).read()
    assert hlo.startswith("HloModule") or "HloModule" in hlo[:200]
    assert "ENTRY" in hlo


def test_train_cache_reused(built):
    cache = os.path.join(built, ".train_cache", "mlp3.params.nbin")
    assert os.path.exists(cache)
    t = nbin.read_nbin(cache)
    assert t["p0.w"].shape == (784, 64)
