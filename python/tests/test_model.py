"""Integer network forward: ref==pallas, fault-mask semantics, lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, luts
from compile.kernels import ref
from compile.kernels.axgemm import axgemm
from compile.model import accuracy_int, build_lowerable, forward_int, predict_int
from compile.networks import ARCHS, activation_shapes, init_params
from compile.quantize import quantize_images, quantize_net


def _mini(net, seed=0):
    arch = ARCHS[net]
    params = init_params(arch, seed)
    calib, _ = datasets.load(arch.dataset, "train", 48)
    q = quantize_net(arch, params, calib, input_scale=1 / 127)
    x, y = datasets.load(arch.dataset, "test", 8)
    return q, quantize_images(x, 1 / 127), y


EXACT = luts.by_name("exact").lut()
KVP = luts.by_name("mul8s_1kvp_s").lut()


@pytest.mark.parametrize("net", ["mlp3", "mlp5", "lenet5"])
def test_ref_vs_pallas_forward(net):
    q, x_q, _ = _mini(net)
    lts = [jnp.asarray(EXACT)] * len(q.qlayers)
    lo_ref = forward_int(q, jnp.asarray(x_q), lts, gemm=ref.axgemm_ref)
    lo_pal = forward_int(q, jnp.asarray(x_q), lts, gemm=axgemm)
    assert np.array_equal(np.asarray(lo_ref), np.asarray(lo_pal))
    assert lo_ref.dtype == jnp.int8 and lo_ref.shape == (8, 10)


def test_mixed_configuration_luts_change_output():
    """Approximating only some layers is a distinct point in design space."""
    q, x_q, _ = _mini("mlp3", seed=2)
    n = len(q.qlayers)
    full_exact = forward_int(q, jnp.asarray(x_q), [jnp.asarray(EXACT)] * n)
    full_axm = forward_int(q, jnp.asarray(x_q), [jnp.asarray(KVP)] * n)
    mixed = forward_int(
        q, jnp.asarray(x_q), [jnp.asarray(KVP), jnp.asarray(EXACT), jnp.asarray(EXACT)]
    )
    assert not np.array_equal(np.asarray(full_exact), np.asarray(full_axm))
    assert not np.array_equal(np.asarray(mixed), np.asarray(full_exact))
    assert not np.array_equal(np.asarray(mixed), np.asarray(full_axm))


def test_zero_mask_is_identity():
    q, x_q, _ = _mini("mlp3")
    n = len(q.qlayers)
    lts = [jnp.asarray(EXACT)] * n
    masks = [jnp.zeros((8, *q.act_shapes[i]), jnp.int8) for i in range(n)]
    a = forward_int(q, jnp.asarray(x_q), lts)
    b = forward_int(q, jnp.asarray(x_q), lts, masks)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_single_bit_mask_flips_one_activation():
    """XOR mask on the last layer flips exactly the targeted logit bit."""
    q, x_q, _ = _mini("mlp3")
    n = len(q.qlayers)
    lts = [jnp.asarray(EXACT)] * n
    base = np.asarray(forward_int(q, jnp.asarray(x_q), lts))
    masks = [None] * n
    m = np.zeros((8, 10), np.int8)
    m[3, 7] = np.int8(np.uint8(1 << 6).view(np.int8))
    masks[n - 1] = jnp.asarray(m)
    got = np.asarray(forward_int(q, jnp.asarray(x_q), lts, masks))
    diff = got.astype(np.int32) ^ base.astype(np.int32)
    assert (diff[3, 7] & 0xFF) == 1 << 6
    diff[3, 7] = 0
    assert (diff == 0).all()


def test_hidden_layer_fault_propagates():
    """A high-bit flip in layer 0 must be able to change the logits."""
    q, x_q, _ = _mini("mlp3", seed=5)
    n = len(q.qlayers)
    lts = [jnp.asarray(EXACT)] * n
    base = np.asarray(forward_int(q, jnp.asarray(x_q), lts))
    masks = [None] * n
    m = np.zeros((8, 64), np.int8)
    m[:, 11] = np.int8(np.uint8(1 << 7).view(np.int8))  # sign bit, every image
    masks[0] = jnp.asarray(m)
    got = np.asarray(forward_int(q, jnp.asarray(x_q), lts, masks))
    assert not np.array_equal(got, base)


def test_predict_int_per_image_mask_broadcast():
    q, x_q, _ = _mini("mlp3")
    n = len(q.qlayers)
    masks = [None] * n
    mm = np.zeros(q.act_shapes[0], np.int8)
    mm[5] = np.int8(np.uint8(1 << 7).view(np.int8))
    masks[0] = mm
    p = predict_int(q, x_q, [EXACT] * n, masks=masks, batch=4)
    assert p.shape == (8,) and p.dtype == np.int32


def test_accuracy_int_bounds():
    q, x_q, y = _mini("mlp3")
    acc = accuracy_int(q, x_q, y, [EXACT] * len(q.qlayers))
    assert 0.0 <= acc <= 1.0


def test_activation_shapes_match_forward():
    for net in ("mlp3", "lenet5", "alexnet"):
        arch = ARCHS[net]
        shapes = activation_shapes(arch)
        assert len(shapes) == len(arch.computing_layers)
        assert shapes[-1] == (10,)


def test_lowerable_signature_and_hlo():
    q, _, _ = _mini("mlp3")
    fn, args = build_lowerable(q, 4)
    assert len(args) == 1 + 2 * len(q.qlayers)
    lowered = jax.jit(fn).lower(*args)
    from compile.aot import to_hlo_text

    hlo = to_hlo_text(lowered)
    assert "ENTRY" in hlo and len(hlo) > 1000


def test_config_template_strings():
    assert ARCHS["mlp3"].config_template == "xxx"
    assert ARCHS["lenet5"].config_template == "x-x-xxx"
    assert ARCHS["alexnet"].config_template == "x-x-xx-x-xxx"
