"""Pallas kernel vs pure-jnp oracle vs scalar numpy — the CORE correctness
signal. Hypothesis sweeps shapes, data distributions and multiplier LUTs."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import luts
from compile.kernels import ref
from compile.kernels.axgemm import axgemm

LUTS = {m.name: m.lut() for m in luts.CATALOG[:4]}
PLANES = {m.name: m.plane() for m in luts.CATALOG[:4]}


def scalar_gemm(a: np.ndarray, w: np.ndarray, plane: np.ndarray) -> np.ndarray:
    """Dead-simple scalar oracle."""
    m, k = a.shape
    _, n = w.shape
    out = np.zeros((m, n), np.int64)
    for i in range(m):
        for j in range(n):
            out[i, j] = sum(int(plane[int(a[i, kk]) + 128, int(w[kk, j]) + 128]) for kk in range(k))
    return out.astype(np.int32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 32),
    n=st.integers(1, 24),
    lut_name=st.sampled_from(list(LUTS)),
    seed=st.integers(0, 2**31 - 1),
)
def test_axgemm_matches_scalar_oracle(m, k, n, lut_name, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (m, k)).astype(np.int8)
    w = rng.integers(-128, 128, (k, n)).astype(np.int8)
    expect = scalar_gemm(a, w, PLANES[lut_name])
    got_ref = np.asarray(ref.axgemm_ref(jnp.asarray(a), jnp.asarray(w), jnp.asarray(LUTS[lut_name])))
    got_pal = np.asarray(axgemm(jnp.asarray(a), jnp.asarray(w), jnp.asarray(LUTS[lut_name])))
    assert np.array_equal(got_ref, expect)
    assert np.array_equal(got_pal, expect)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(100, 400),
    block_m=st.sampled_from([32, 64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_axgemm_blocking_invariance(m, block_m, seed):
    """Output independent of the M-tile size, including ragged tails."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, (m, 17)).astype(np.int8)
    w = rng.integers(-128, 128, (17, 9)).astype(np.int8)
    lut = jnp.asarray(LUTS["mul8s_1kv9_s"])
    base = np.asarray(ref.axgemm_ref(jnp.asarray(a), jnp.asarray(w), lut))
    got = np.asarray(axgemm(jnp.asarray(a), jnp.asarray(w), lut, block_m=block_m))
    assert np.array_equal(got, base)


def test_axgemm_extreme_values():
    """Full-scale corners: -128*-128 etc. accumulate without overflow."""
    a = np.full((4, 64), -128, np.int8)
    w = np.full((64, 4), -128, np.int8)
    out = np.asarray(axgemm(jnp.asarray(a), jnp.asarray(w), jnp.asarray(LUTS["exact"])))
    assert (out == 64 * 16384).all()


def test_axgemm_ref_cube_and_scan_paths_agree():
    """ref has a vectorized small-path and a scan big-path; force both."""
    rng = np.random.default_rng(0)
    a = rng.integers(-128, 128, (64, 80)).astype(np.int8)
    w = rng.integers(-128, 128, (80, 48)).astype(np.int8)
    lut = jnp.asarray(LUTS["mul8s_1kvp_s"])
    small = np.asarray(ref.axgemm_ref(jnp.asarray(a), jnp.asarray(w), lut))
    old = ref._CUBE_BUDGET
    try:
        ref._CUBE_BUDGET = 0  # force scan path
        big = np.asarray(ref.axgemm_ref(jnp.asarray(a), jnp.asarray(w), lut))
    finally:
        ref._CUBE_BUDGET = old
    assert np.array_equal(small, big)


@settings(max_examples=15, deadline=None)
@given(
    m0r=st.floats(1e-5, 0.9999),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_requantize_matches_scalar(m0r, relu, seed):
    from compile.quantize import requant_params

    m0, n = requant_params(m0r)
    rng = np.random.default_rng(seed)
    acc = rng.integers(-(2**22), 2**22, 64).astype(np.int32)
    got = np.asarray(ref.requantize(jnp.asarray(acc), m0, n, relu))
    expect = np.clip((acc.astype(np.int64) * m0 + (1 << (n - 1))) >> n, -128, 127).astype(np.int8)
    if relu:
        expect = np.maximum(expect, 0)
    assert np.array_equal(got, expect)


def naive_im2col(x, k, stride, pad):
    b, c, h, w = x.shape
    xp = np.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    out = np.zeros((b * oh * ow, c * k * k), x.dtype)
    r = 0
    for bi in range(b):
        for oy in range(oh):
            for ox in range(ow):
                for ci in range(c):
                    for ky in range(k):
                        for kx in range(k):
                            out[r, (ci * k + ky) * k + kx] = xp[
                                bi, ci, oy * stride + ky, ox * stride + kx
                            ]
                r += 1
    return out


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 4),
    h=st.integers(4, 12),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_matches_naive(c, h, k, stride, pad, seed):
    if h + 2 * pad < k:
        return
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (2, c, h, h)).astype(np.int8)
    got = np.asarray(ref.im2col(jnp.asarray(x), k, stride, pad))
    expect = naive_im2col(x, k, stride, pad)
    assert np.array_equal(got, expect)


def test_maxpool_i8():
    x = np.array(
        [[[[1, 2, 3, 4], [5, 6, 7, 8], [-1, -2, -3, -4], [-5, -6, -128, 127]]]],
        np.int8,
    )
    got = np.asarray(ref.maxpool_i8(jnp.asarray(x), 2))
    assert got.tolist() == [[[[6, 8], [-1, 127]]]]


def test_conv_via_im2col_matches_float_conv_shape():
    """Geometry check: im2col GEMM output reshapes to the lax.conv shape."""
    import jax

    x = np.zeros((2, 3, 8, 8), np.int8)
    cols = np.asarray(ref.im2col(jnp.asarray(x), 3, 1, 1))
    assert cols.shape == (2 * 8 * 8, 3 * 9)
