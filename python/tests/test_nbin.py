"""nbin container format: roundtrip + error handling (format is shared with
rust/src/nbin.rs; rust unit tests pin the same byte layout)."""

import numpy as np
import pytest

from compile import nbin


def test_roundtrip_all_dtypes(tmp_path):
    path = str(tmp_path / "t.nbin")
    tensors = {
        "a_i8": np.arange(-8, 8, dtype=np.int8).reshape(4, 4),
        "b_u8": np.arange(16, dtype=np.uint8).reshape(2, 8),
        "c_i32": np.arange(-4, 4, dtype=np.int32).reshape(2, 2, 2),
        "d_i64": np.array([2**40, -(2**40)], dtype=np.int64),
        "e_f32": np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
        "f_f64": np.array([[1.5, -2.5]], dtype=np.float64),
    }
    nbin.write_nbin(path, tensors)
    back = nbin.read_nbin(path)
    assert set(back) == set(tensors)
    for k in tensors:
        assert back[k].dtype == tensors[k].dtype, k
        assert back[k].shape == tensors[k].shape, k
        assert np.array_equal(back[k], tensors[k]), k


def test_scalar_and_empty(tmp_path):
    path = str(tmp_path / "t.nbin")
    nbin.write_nbin(path, {"s": np.array(7, np.int32), "e": np.zeros((0, 3), np.int8)})
    back = nbin.read_nbin(path)
    assert back["s"].shape == ()
    assert int(back["s"]) == 7
    assert back["e"].shape == (0, 3)


def test_bad_magic(tmp_path):
    path = str(tmp_path / "bad.nbin")
    with open(path, "wb") as f:
        f.write(b"NOTNBIN")
    with pytest.raises(ValueError, match="bad magic"):
        nbin.read_nbin(path)


def test_unsupported_dtype(tmp_path):
    with pytest.raises(ValueError, match="unsupported dtype"):
        nbin.write_nbin(str(tmp_path / "x.nbin"), {"x": np.zeros(2, np.float16)})


def test_truncated_payload(tmp_path):
    path = str(tmp_path / "t.nbin")
    nbin.write_nbin(path, {"x": np.arange(100, dtype=np.int32)})
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[:-10])
    with pytest.raises(ValueError, match="truncated"):
        nbin.read_nbin(path)


def test_unicode_names(tmp_path):
    path = str(tmp_path / "t.nbin")
    nbin.write_nbin(path, {"weights/λ0": np.ones(3, np.float32)})
    assert "weights/λ0" in nbin.read_nbin(path)
