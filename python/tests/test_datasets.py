"""Synthetic dataset generators: shapes, determinism, class structure."""

import numpy as np

from compile import datasets


def test_synmnist_shapes_and_range():
    x, y = datasets.synmnist(32, seed=5)
    assert x.shape == (32, 1, 28, 28) and x.dtype == np.float32
    assert y.shape == (32,) and y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert y.min() >= 0 and y.max() <= 9


def test_syncifar_shapes_and_range():
    x, y = datasets.syncifar(24, seed=6)
    assert x.shape == (24, 3, 32, 32) and x.dtype == np.float32
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_determinism():
    a1, l1 = datasets.synmnist(16, seed=9)
    a2, l2 = datasets.synmnist(16, seed=9)
    assert np.array_equal(a1, a2) and np.array_equal(l1, l2)
    b1, _ = datasets.syncifar(16, seed=9)
    b2, _ = datasets.syncifar(16, seed=9)
    assert np.array_equal(b1, b2)


def test_seed_sensitivity():
    a1, _ = datasets.synmnist(16, seed=1)
    a2, _ = datasets.synmnist(16, seed=2)
    assert not np.array_equal(a1, a2)


def test_all_classes_present():
    _, y = datasets.synmnist(400, seed=3)
    assert set(y.tolist()) == set(range(10))
    _, y = datasets.syncifar(400, seed=3)
    assert set(y.tolist()) == set(range(10))


def test_train_test_disjoint_seeds():
    xtr, _ = datasets.load("synmnist", "train", 8)
    xte, _ = datasets.load("synmnist", "test", 8)
    assert not np.array_equal(xtr, xte)


def test_intra_class_variability():
    """Same digit renders differently (jitter) — required for a non-trivial
    learning problem."""
    rng_imgs = []
    x, y = datasets.synmnist(200, seed=12)
    for d in range(10):
        imgs = x[y == d]
        if len(imgs) >= 2:
            assert not np.array_equal(imgs[0], imgs[1])


def test_classes_distinguishable_by_template():
    """Nearest-class-mean on raw pixels beats chance by a wide margin —
    sanity that the task is learnable."""
    xtr, ytr = datasets.synmnist(500, seed=31)
    xte, yte = datasets.synmnist(200, seed=32)
    means = np.stack([xtr[ytr == d].mean(axis=0).ravel() for d in range(10)])
    preds = np.argmin(
        ((xte.reshape(len(xte), -1)[:, None, :] - means[None]) ** 2).sum(-1), axis=1
    )
    # the jitter/noise level targets a quantized-MLP accuracy near the
    # paper's 80% baseline, so a linear template matcher sits well below a
    # trained net but far above the 10% chance level
    assert (preds == yte).mean() > 0.3
