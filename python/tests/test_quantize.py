"""Quantization: requant fixed-point params, layouts, end-to-end fidelity."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets
from compile.kernels import ref
from compile.networks import ARCHS, forward_float, init_params
from compile.quantize import QNet, quantize_images, quantize_net, requant_params


def test_requant_params_range():
    for r in (1e-6, 1e-3, 0.1, 0.5, 0.99, 1.0, 3.7, 100.0):
        m0, n = requant_params(r)
        assert 0 <= n <= 62
        assert m0 < 1 << 31
        # reconstruction error small
        assert abs(m0 / (1 << n) - r) / r < 1e-6 or n == 62


def test_requant_params_rejects_nonpositive():
    with pytest.raises(ValueError):
        requant_params(0.0)
    with pytest.raises(ValueError):
        requant_params(-1.0)


def test_requant_rounding_semantics():
    """(acc * m0 + 2^(n-1)) >> n must round-half-up like the rust engine."""
    m0, n = requant_params(0.5)
    acc = jnp.array([-3, -2, -1, 0, 1, 2, 3], jnp.int32)
    y = ref.requantize(acc, m0, n, relu=False)
    # 0.5*acc rounded half-up: -1.5 -> -1, -1 -> -1, -0.5 -> 0, ...
    assert y.tolist() == [-1, -1, 0, 0, 1, 1, 2]


def test_quantize_images_clip():
    x = np.array([[-2.0, 0.0, 0.5, 1.0, 2.0]], np.float32)
    q = quantize_images(x, 1.0 / 127.0)
    assert q.tolist() == [[-128, 0, 64, 127, 127]]
    assert q.dtype == np.int8


def _mini_qnet(net="mlp3", seed=0, n_calib=64):
    arch = ARCHS[net]
    params = init_params(arch, seed)
    calib, _ = datasets.load(arch.dataset, "train", n_calib)
    return arch, params, quantize_net(arch, params, calib, input_scale=1 / 127)


def test_qnet_structure():
    arch, params, q = _mini_qnet()
    assert isinstance(q, QNet)
    assert len(q.qlayers) == len(arch.computing_layers)
    for ql in q.qlayers:
        assert ql.w_q.dtype == np.int8
        assert ql.b_q.dtype == np.int32
        assert np.abs(ql.w_q).max() <= 127
        assert 1 << 30 <= ql.m0 < 1 << 31 or ql.nshift == 62


def test_scale_chaining():
    """Layer l+1 input scale == layer l output scale."""
    _, _, q = _mini_qnet("mlp5")
    for prev, cur in zip(q.qlayers, q.qlayers[1:]):
        assert cur.s_in == pytest.approx(prev.s_out)


def test_conv_weight_gemm_layout():
    """Conv weights exported as [K, N] with K = (ci*k + ky)*k + kx."""
    arch, params, q = _mini_qnet("lenet5")
    # first conv: OIHW [6, 1, 5, 5]
    w = params[0][0]
    ql = q.qlayers[0]
    assert ql.w_q.shape == (1 * 5 * 5, 6)
    s_w = ql.s_w
    for co in (0, 3, 5):
        for ci in (0,):
            for ky in (0, 2, 4):
                for kx in (1, 3):
                    kidx = (ci * 5 + ky) * 5 + kx
                    expect = int(np.clip(np.round(w[co, ci, ky, kx] / s_w), -127, 127))
                    assert ql.w_q[kidx, co] == expect


def test_quantized_forward_tracks_float():
    """Integer forward (exact LUT) approximates the float forward: the
    argmax agrees on a clear majority of easy inputs even for an untrained
    net (logit ordering is scale-invariant)."""
    from compile import luts
    from compile.model import forward_int

    arch, params, q = _mini_qnet("mlp3", seed=3)
    x, _ = datasets.load(arch.dataset, "test", 64)
    x_q = quantize_images(x, 1 / 127)
    jl = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params]
    fl = np.asarray(jnp.argmax(forward_float(arch, jl, jnp.asarray(x)), axis=-1))
    exact = [jnp.asarray(luts.by_name("exact").lut())] * len(q.qlayers)
    il = np.asarray(jnp.argmax(forward_int(q, jnp.asarray(x_q), exact), axis=-1))
    assert (fl == il).mean() > 0.75


def test_meta_serialization_roundtrip_fields():
    from compile.quantize import qnet_meta, qnet_tensors

    arch, params, q = _mini_qnet("lenet5")
    meta = qnet_meta(q)
    assert meta["name"] == "lenet5"
    assert meta["n_comp_layers"] == 5
    assert meta["config_template"] == "x-x-xxx"
    kinds = [l["kind"] for l in meta["layers"]]
    assert kinds == ["conv", "pool", "conv", "pool", "flatten", "dense", "dense", "dense"]
    tensors = qnet_tensors(q)
    assert set(tensors) == {f"l{i}.{s}" for i in range(5) for s in ("w", "b")}
    for l in meta["layers"]:
        if l["kind"] in ("conv", "dense"):
            assert l["m0"] > 0 and 0 <= l["nshift"] <= 62
            assert l["k_dim"] == tensors[f"l{l['comp_index']}.w"].shape[0]
