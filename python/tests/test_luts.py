"""Approximate-multiplier library: LUT layout, exactness, calibration."""

import numpy as np
import pytest

from compile import luts


def test_exact_plane_is_product():
    p = luts.plane_exact()
    for a in (-128, -1, 0, 1, 127, 37):
        for b in (-128, -5, 0, 2, 127):
            assert p[a + 128, b + 128] == a * b


def test_lut_byte_order_indexing():
    """lut[(a_u8 << 8) | b_u8] must equal mult(a, b) for signed a, b."""
    for m in luts.CATALOG:
        lut = m.lut()
        plane = m.plane()
        rng = np.random.default_rng(1)
        for _ in range(200):
            a = int(rng.integers(-128, 128))
            b = int(rng.integers(-128, 128))
            idx = ((a & 0xFF) << 8) | (b & 0xFF)
            assert lut[idx] == plane[a + 128, b + 128], (m.name, a, b)


def test_exact_metrics_zero():
    met = luts.error_metrics(luts.plane_exact())
    assert met["mae"] == 0 and met["wce"] == 0 and met["ep_pct"] == 0


def test_bam_underestimates_magnitude():
    """BAM drops partial products, so |approx| <= |exact| always."""
    for k in (2, 3, 4):
        p = luts.plane_bam(k)
        e = luts.plane_exact()
        assert (np.abs(p) <= np.abs(e)).all()
        # sign is preserved (or result is zero)
        assert (np.sign(p) * np.sign(e) >= 0).all()


def test_bam_monotone_error_in_k():
    prev = -1.0
    for k in (1, 2, 3, 4, 5, 6):
        mae = luts.error_metrics(luts.plane_bam(k))["mae"]
        assert mae > prev
        prev = mae


def test_catalog_calibration_ordering():
    """Surrogates must preserve the paper's error ordering:
    1KVP >> 1KV9 >> 1KV8 on every metric."""
    met = {m.name: luts.error_metrics(m.plane()) for m in luts.CATALOG[:4]}
    for key in ("mae", "wce", "mre_pct"):
        assert (
            met["mul8s_1kvp_s"][key]
            > met["mul8s_1kv9_s"][key]
            > met["mul8s_1kv8_s"][key]
            > met["exact"][key]
        ), key


def test_catalog_ep_matches_paper_exactly():
    """bam(3)/bam(2) were calibrated to land exactly on the paper's EP."""
    met9 = luts.error_metrics(luts.by_name("mul8s_1kv9_s").plane())
    met8 = luts.error_metrics(luts.by_name("mul8s_1kv8_s").plane())
    assert met9["ep_pct"] == pytest.approx(68.75, abs=0.01)
    assert met8["ep_pct"] == pytest.approx(50.00, abs=0.01)


def test_rndpp_error_bound():
    for k in (2, 3, 4):
        p = luts.plane_rndpp(k)
        e = luts.plane_exact()
        assert np.abs(p - e).max() <= (1 << (k - 1))


def test_trunc_zero_preserving():
    p = luts.plane_trunc(3)
    assert p[0 + 128, :].max() == 0 and p[:, 0 + 128].max() == 0


def test_mitchell_reasonable():
    met = luts.error_metrics(luts.plane_mitchell())
    # Mitchell's classic worst-case relative error is ~11.1%
    assert met["mre_pct"] < 11.2
    assert met["mae"] > 0


def test_by_name_raises():
    with pytest.raises(KeyError):
        luts.by_name("nope")


def test_catalog_report_fields():
    rows = luts.catalog_report()
    assert {r["name"] for r in rows} >= {"exact", "mul8s_1kvp_s", "mul8s_1kv9_s", "mul8s_1kv8_s"}
    for r in rows:
        for f in ("mae", "wce", "mre_pct", "ep_pct", "power_mw", "area_um2"):
            assert f in r
