#!/usr/bin/env bash
# Perf-trajectory recorder: runs the bench harnesses (bench_zoo,
# bench_faultsim, bench_eval, bench_hotpath) and collects every
# machine-readable JSON line they emit into BENCH_<n>.json at the repo
# root (n = first unused index), so faults/s, mean replay depth,
# delta-patch speedup and points/s per fidelity tier are recorded across
# PRs instead of scrolling away.
#
#   scripts/bench.sh            full bench run (needs cargo + artifacts)
#   scripts/bench.sh --smoke    tiny env knobs so the whole sweep runs in
#                               seconds; exits 0 (records what it can)
#                               when the toolchain or artifacts are
#                               missing — the variant scripts/ci.sh wires
#                               in.
#
# bench_zoo needs no artifacts (nets + workloads are generated from
# seeds), so it is recorded unconditionally; the artifact-gated benches
# follow when ./artifacts exists. bench_faultsim additionally records
# per-fault-model faults/s ("model-bitflip" / "model-stuckat" /
# "model-lutplane" / "model-multibit" config records) on a generated net,
# so the zoo of fault models gets a perf trajectory alongside the
# replay/delta/gate knobs. PR 7 adds `batch_speedup_vs_scalar` (batched
# LUT-GEMM forward + fault-major group replay vs the per-image scalar
# loops) and `simd_speedup_vs_scalar` (portable-SIMD kernels on vs off;
# ~1.0 when the `simd` cargo feature is not compiled in) to both
# bench_hotpath and bench_faultsim. PR 8 adds `checkpoint_overhead_pct`
# to bench_zoo: the same zoo search run plain and under a write-ahead
# run journal committing every generation, so the cost of the crash-safe
# default is tracked across PRs. PR 9 adds bench_search to the
# unconditional list: its artifact-free async A/B record asserts
# sync/async bit-identity in-process, then emits
# `async_speedup_vs_sync`, `executor_idle_pct` and `executor_steals`
# (the lenet5 grid half of bench_search still needs artifacts and skips
# itself when they are absent). PR 10 adds `partition_speedup_vs_single`
# to bench_search: the same exhaustive sweep as one process vs four
# serve::run_shard workers, merge identity asserted in-process first.
#
# Record shape: {"schema":"deepaxe-bench-v1","run":N,"smoke":0|1,
# "records":[...one object per emitted line...]}. The per-record fields
# come from the benches themselves (bench/config/metric keys).
set -euo pipefail
cd "$(dirname "$0")/.."

SMOKE=0
if [ "${1:-}" = "--smoke" ]; then
    SMOKE=1
fi

skip() {
    echo "bench.sh: $1" >&2
    if [ "$SMOKE" = 1 ]; then
        echo "bench.sh: smoke mode — skipping bench run." >&2
        exit 0
    fi
    exit 1
}

command -v cargo >/dev/null 2>&1 || skip "cargo not found on PATH"

if [ "$SMOKE" = 1 ]; then
    export DEEPAXE_FI_FAULTS="${DEEPAXE_FI_FAULTS:-8}"
    export DEEPAXE_FI_IMAGES="${DEEPAXE_FI_IMAGES:-8}"
    export DEEPAXE_EVAL_IMAGES="${DEEPAXE_EVAL_IMAGES:-16}"
fi

n=0
while [ -e "BENCH_$n.json" ]; do
    n=$((n + 1))
done
out="BENCH_$n.json"
lines="$(mktemp)"
trap 'rm -f "$lines"' EXIT

run_bench() {
    echo "== bench.sh: cargo bench --bench $1 =="
    # benches print human lines + one JSON object per measurement; keep
    # the human output on the terminal, collect the JSON. Only grep's
    # no-match status is forgiven — a bench failure (the in-bench
    # bit-identity assertions included) still fails the run via pipefail.
    cargo bench --bench "$1" | tee /dev/stderr | { grep '^{' || true; } >> "$lines"
}

write_out() {
    {
        printf '{"schema":"deepaxe-bench-v1","run":%s,"smoke":%s,"records":[' "$n" "$SMOKE"
        paste -sd, "$lines"
        printf ']}\n'
    } > "$out"
    echo "bench.sh: wrote $out ($(wc -l < "$lines" | tr -d ' ') records)"
}

# artifact-free: always recorded (these are the records --smoke keeps;
# bench_search skips its artifact-gated lenet5 half on its own)
run_bench bench_zoo
run_bench bench_search

ARTIFACTS="${DEEPAXE_ARTIFACTS:-artifacts}"
if [ ! -f "$ARTIFACTS/manifest.json" ]; then
    # keep the zoo records either way — they were already measured
    echo "bench.sh: artifacts missing ($ARTIFACTS/manifest.json) — zoo records only." >&2
    write_out
    if [ "$SMOKE" = 1 ]; then
        exit 0
    fi
    echo "bench.sh: run \`make artifacts\` for the artifact-gated benches." >&2
    exit 1
fi

for b in bench_faultsim bench_eval bench_hotpath; do
    run_bench "$b"
done

write_out
