#!/usr/bin/env bash
# Tier-1 gate: release build + test suite, plus style stages (format and
# clippy) when the respective toolchain components are installed. Run from
# anywhere; operates on the repo root.
#
# Knobs:
#   CI_SKIP_FMT=1     skip the cargo fmt --check step
#   CI_SKIP_CLIPPY=1  skip the cargo clippy step
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: cargo not found on PATH — tier-1 cannot run in this image." >&2
    echo "ci.sh: install the rust toolchain (rustc >= 1.73) and re-run." >&2
    exit 1
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: zoo-backed integration tests (artifact-free, no skip) =="
# The zoo_ suites generate their nets and workloads from seeds, so they
# run in every container — with or without ./artifacts.
cargo test -q --test integration_search zoo_
cargo test -q --test integration_faultsim zoo_
cargo test -q --test integration_cli zoo_

echo "== tier-1: crash-safe recovery integration tests (artifact-free, no skip) =="
# The recovery_ suite covers the journaled checkpoint/resume runtime:
# kill-and-resume bit-identity (with and without FI screening) and
# poisoned design-point quarantine + replay — zoo-generated nets only,
# so it runs in every container.
cargo test -q --test integration_search recovery_

echo "== tier-1: async-runtime integration tests (artifact-free, no skip) =="
# The async_ suite pins the barrier-free planner/executor runtime to the
# --sync generational path: bit-identical archive, frontier, budget, and
# FI ledger at any worker count (screen on/off), pipelined exhaustive
# parity, and cross-mode journal resume — zoo-generated nets only.
cargo test -q --test integration_search async_

echo "== tier-1: serve/worker/merge integration tests (artifact-free, no skip) =="
# The serve_ suite covers the DSE-as-a-service subsystem: deterministic
# space partitioning (incl. ragged-N property tests in the serve:: unit
# suite), shard-then-merge bit-identity against the single-process sweep,
# worker journal resume + runs listing, and the Unix-socket job-queue
# daemon (submit/status/snapshot/cancel/shutdown, frozen-checkpoint
# resume) — zoo-generated nets only, so it runs in every container.
cargo test -q --lib serve::
cargo test -q --test integration_search serve_

echo "== tier-1: fault-model zoo integration tests (artifact-free, no skip) =="
# The fault_model_ suite covers the unified FaultModel subsystem (bitflip
# bit-for-bit parity, stuck-at/multibit/lutplane campaigns, selective
# hardening) on generated nets — runs in every container.
cargo test -q --test integration_faultsim fault_model_

echo "== tier-1: cargo test -q =="
# Integration tests additionally need ./artifacts (make artifacts); unit
# tests run regardless.
cargo test -q

echo "== tier-1: --features simd build + test (skipped without std::simd) =="
# The `simd` feature turns on portable-SIMD kernels (nightly
# `portable_simd`); the scalar path is always compiled and bit-identical,
# so a toolchain without std::simd just skips this stage. The probe is a
# real (cached) build, not a version sniff — whatever toolchain is
# installed decides.
if cargo build --release --features simd >/dev/null 2>&1; then
    cargo test -q --features simd
else
    echo "ci.sh: toolchain lacks std::simd (portable_simd); skipping the simd stage." >&2
fi

echo "== tier-1: cargo bench --no-run =="
# Benches are harness-less binaries that only run with artifacts present;
# compiling them here keeps bench_faultsim & friends from silently rotting.
cargo bench --no-run

echo "== perf: scripts/bench.sh --smoke =="
# Tiny-knob bench sweep recording BENCH_<n>.json (faults/s, replay depth,
# delta speedup, points/s per tier). The artifact-free bench_zoo record is
# always collected; the artifact-gated benches are skipped (exit 0) when
# ./artifacts is absent.
scripts/bench.sh --smoke

if [ "${CI_SKIP_FMT:-0}" != "1" ]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== style: cargo fmt --check =="
        cargo fmt --check
    else
        echo "ci.sh: rustfmt not installed; skipping format check." >&2
    fi
fi

if [ "${CI_SKIP_CLIPPY:-0}" != "1" ]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== style: cargo clippy -D warnings =="
        cargo clippy --all-targets -- -D warnings
    else
        echo "ci.sh: clippy not installed; skipping lint check." >&2
    fi
fi

echo "ci.sh: all checks passed"
